#!/usr/bin/env python3
"""Python mirror of d3lint (rust/tools/d3lint/src).

The Rust implementation is authoritative — CI runs `cargo run -p d3lint
-- --check-baseline` and the crate's own test suite asserts the committed
`lint-baseline.toml` matches the tree. This mirror exists because some
authoring containers for this repo ship no Rust toolchain at all (see
.claude/skills/verify/SKILL.md): it ports the exact same scan algorithm
so the baseline can be regenerated and rule changes validated without
cargo. Keep the two in lockstep token-for-token; the baseline test in
rust/tools/d3lint/tests/lint_rules.rs is the drift alarm.

Usage:
  python3 rust/tools/d3lint/mirror.py                # list findings
  python3 rust/tools/d3lint/mirror.py --write-baseline
  python3 rust/tools/d3lint/mirror.py --check-baseline
"""

import os
import sys

# ---------------------------------------------------------------- scopes
# (keep identical to rust/tools/d3lint/src/rules.rs)

DET_SCOPES = [
    "rust/src/decode/",
    "rust/src/coordinator/scheduler.rs",
    "rust/src/coordinator/batcher.rs",
    "rust/src/model/kv_pool.rs",
]
PANIC_SCOPES = ["rust/src/coordinator/", "rust/src/decode/session.rs"]
ORDERING_SCOPES = ["rust/src/coordinator/"]

DET_TOKENS = ["HashMap", "HashSet", "Instant::now()", "SystemTime"]
PANIC_TOKENS = [".unwrap()", ".expect(", "panic!(", "unreachable!("]
ORDERING_TOKENS = [
    "Ordering::SeqCst", "Ordering::Acquire", "Ordering::Release",
    "Ordering::AcqRel",
]

ABI_RUST_FILES = ["rust/src/model/exec.rs", "rust/src/runtime/manifest.rs"]
EXEC_NAME_PREFIXES = ["prefill", "decode", "train", "trajectory", "ar_",
                      "draft_"]

IDENT = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")


class Finding:
    def __init__(self, file, line, rule, message):
        self.file, self.line, self.rule, self.message = file, line, rule, message

    def render(self):
        return f"{self.file}:{self.line} {self.rule} {self.message}"


# ------------------------------------------------------- rust line model

class Line:
    """One source line after comment/string stripping.

    code:     source text with comment text removed and string/char
              literal *contents* removed (delimiters kept).
    comment:  concatenated text of all comments on the line.
    strings:  contents of string literals that *start* on this line.
    in_test:  line is inside a #[cfg(test)]-gated item.
    """

    def __init__(self):
        self.code = ""
        self.comment = ""
        self.strings = []
        self.in_test = False


def close_string(lines, current, buf):
    start, chars = buf
    target = current if start == len(lines) else lines[start]
    target.strings.append("".join(chars))


def strip_rust(text):
    """Split each line into code / comment / string-literal parts and mark
    #[cfg(test)] regions by brace counting. Mirrors scan.rs exactly."""
    lines = []
    block_depth = 0        # /* */ nesting
    raw_hashes = None      # inside r#".."# string: number of hashes
    in_str = False         # inside a normal "..." string
    str_buf = None         # (start_line_index, [chars]) of the open string
    depth = 0              # brace depth over code
    test_depth = None      # brace depth at which a cfg(test) region opened
    pending_test = False   # saw #[cfg(test)], waiting for its '{'

    for raw in text.split("\n"):
        ln = Line()
        was_in_test = test_depth is not None
        i, n = 0, len(raw)
        while i < n:
            c = raw[i]
            if in_str:
                if c == "\\" and i + 1 < n:
                    str_buf[1].append(raw[i:i + 2])
                    i += 2
                    continue
                if c == '"':
                    in_str = False
                    ln.code += '"'
                    close_string(lines, ln, str_buf)
                    str_buf = None
                else:
                    str_buf[1].append(c)
                i += 1
                continue
            if raw_hashes is not None:
                term = '"' + "#" * raw_hashes
                if raw.startswith(term, i):
                    ln.code += '"' + "#" * raw_hashes
                    close_string(lines, ln, str_buf)
                    str_buf = None
                    i += len(term)
                    raw_hashes = None
                else:
                    str_buf[1].append(c)
                    i += 1
                continue
            if block_depth > 0:
                if raw.startswith("*/", i):
                    block_depth -= 1
                    i += 2
                elif raw.startswith("/*", i):
                    block_depth += 1
                    i += 2
                else:
                    ln.comment += c
                    i += 1
                continue
            # ---- code context
            if raw.startswith("//", i):
                ln.comment += raw[i + 2:]
                break
            if raw.startswith("/*", i):
                block_depth += 1
                i += 2
                continue
            if c == "r":
                j = i + 1
                while j < n and raw[j] == "#":
                    j += 1
                if j < n and raw[j] == '"':
                    raw_hashes = j - i - 1
                    ln.code += 'r' + "#" * raw_hashes + '"'
                    str_buf = (len(lines), [])
                    i = j + 1
                    continue
            if c == '"':
                in_str = True
                ln.code += '"'
                str_buf = (len(lines), [])
                i += 1
                continue
            if c == "'":
                # char literal vs lifetime: '\x..' or 'x' is a literal
                if i + 1 < n and raw[i + 1] == "\\":
                    j = raw.find("'", i + 2)
                    ln.code += "''"
                    i = (j + 1) if j != -1 else n
                    continue
                if i + 2 < n and raw[i + 2] == "'":
                    ln.code += "''"
                    i += 3
                    continue
                ln.code += c    # lifetime
                i += 1
                continue
            ln.code += c
            i += 1
        # cfg(test) tracking (before brace effects of this line landed we
        # may set pending; the region starts at its opening brace)
        if test_depth is None and "cfg(test)" in ln.code:
            pending_test = True
        for ch in ln.code:
            if ch == "{":
                if pending_test and test_depth is None:
                    test_depth = depth
                    pending_test = False
                depth += 1
            elif ch == "}":
                depth -= 1
                if test_depth is not None and depth == test_depth:
                    test_depth = None
        ln.in_test = was_in_test or test_depth is not None
        lines.append(ln)
    return lines


def in_scope(rel, scopes):
    return any(rel == s or rel.startswith(s) for s in scopes)


def count_occurrences(hay, needle):
    c = start = 0
    while True:
        k = hay.find(needle, start)
        if k == -1:
            return c
        c += 1
        start = k + len(needle)


def is_index_bracket(code, i):
    return i > 0 and (code[i - 1] in IDENT or code[i - 1] in ")]")


def allowed(rule, comment, prev_comment):
    marker = f"lint: allow({rule})"
    return marker in comment or marker in prev_comment


# ---------------------------------------------------------------- rules

def scan_rust_file(rel, text):
    findings = []
    lines = strip_rust(text)
    # `prev_comment` carries the whole comment block directly above the
    # line: consecutive code-less lines accumulate, any code line resets
    prev_comment = ""

    def carry(prev, ln):
        return prev + ln.comment if not ln.code.strip() else ln.comment

    for idx, ln in enumerate(lines):
        lineno = idx + 1
        if ln.in_test:
            prev_comment = carry(prev_comment, ln)
            continue
        if in_scope(rel, DET_SCOPES) and \
                not allowed("determinism", ln.comment, prev_comment):
            for tok in DET_TOKENS:
                for _ in range(count_occurrences(ln.code, tok)):
                    findings.append(Finding(
                        rel, lineno, "determinism",
                        f"'{tok}' in a determinism-scoped path "
                        "(virtual clock / ordered maps only)"))
        if in_scope(rel, PANIC_SCOPES) and \
                not allowed("panic-path", ln.comment, prev_comment):
            for tok in PANIC_TOKENS:
                for _ in range(count_occurrences(ln.code, tok)):
                    findings.append(Finding(
                        rel, lineno, "panic-path",
                        f"'{tok}' in a serving path (degrade to an error "
                        "reply instead)"))
            for i, ch in enumerate(ln.code):
                if ch == "[" and is_index_bracket(ln.code, i):
                    findings.append(Finding(
                        rel, lineno, "panic-path",
                        "direct indexing in a serving path (use .get())"))
        if in_scope(rel, ORDERING_SCOPES):
            justified = ("ordering:" in ln.comment
                         or "ordering:" in prev_comment)
            if not justified:
                for tok in ORDERING_TOKENS:
                    for _ in range(count_occurrences(ln.code, tok)):
                        findings.append(Finding(
                            rel, lineno, "atomic-ordering",
                            f"'{tok}' without an '// ordering:' "
                            "justification comment"))
        prev_comment = carry(prev_comment, ln)
    return findings


# ---------------------------------------------------------- ABI analysis

def exec_name_ref(s):
    """Classify a string literal as an exec-name reference.
    Returns ('exact', name) | ('prefix', p) | None."""
    if not s or any(ch not in "abcdefghijklmnopqrstuvwxyz0123456789_{}"
                    for ch in s):
        return None
    if not any(s.startswith(p) for p in EXEC_NAME_PREFIXES):
        return None
    if "{" in s:
        p = s.split("{", 1)[0]
        return ("prefix", p) if p else None
    if s.endswith("_"):
        return ("prefix", s)
    if "_" in s or s == "trajectory":
        return ("exact", s)
    return None


def balanced_call(lines, start_idx, open_pos):
    """Collect text of a call from its '(' to the matching ')'."""
    depth = 0
    out = []
    idx, pos = start_idx, open_pos
    while idx < len(lines):
        line = lines[idx]
        while pos < len(line):
            ch = line[pos]
            out.append(ch)
            if ch in "([":
                depth += 1
            elif ch in ")]":
                depth -= 1
                if depth == 0:
                    return "".join(out)
            pos += 1
        out.append(" ")
        idx += 1
        pos = 0
    return "".join(out)


NAME_CHARS = set("abcdefghijklmnopqrstuvwxyz0123456789_")


def quoted_strings(line):
    """Sequentially paired "..." contents (values never contain quotes in
    the files this parses)."""
    out = []
    i = 0
    while True:
        a = line.find('"', i)
        if a == -1:
            return out
        b = line.find('"', a + 1)
        if b == -1:
            return out
        out.append((line[a + 1:b], b + 1))
        i = b + 1


def lowercase_names(line):
    return [s for s, _ in quoted_strings(line)
            if all(ch in NAME_CHARS for ch in s)]


def quoted_keys(line):
    """Quoted strings immediately followed by ':' (dict keys)."""
    return [s for s, end in quoted_strings(line)
            if end < len(line) and line[end] == ":"
            and s and all(ch in NAME_CHARS for ch in s)]


def has_assignment(line, var):
    """`var = ...` at a token boundary."""
    i = 0
    while True:
        k = line.find(var, i)
        if k == -1:
            return False
        before_ok = k == 0 or line[k - 1] not in IDENT
        j = k + len(var)
        while j < len(line) and line[j] == " ":
            j += 1
        if before_ok and j < len(line) and line[j] == "=" \
                and (j + 1 >= len(line) or line[j + 1] != "="):
            return True
        i = k + len(var)


def int_after(line, marker):
    k = line.find(marker)
    if k == -1:
        return None
    j = k + len(marker)
    while j < len(line) and line[j] == " ":
        j += 1
    d = ""
    while j < len(line) and line[j].isdigit():
        d += line[j]
        j += 1
    return int(d) if d else None


class PySpecs:
    def __init__(self):
        self.names = {}          # name -> (line, arity_ok)
        self.exec_meta = []      # (key, line)
        self.constants = []      # key names
        self.format_version = None
        self.fv_line = 0
        self.errors = []         # Finding


def parse_aot(rel, text):
    out = PySpecs()
    lines = text.split("\n")
    variants, prefixes, wnames, tnames = [], [], [], []
    for idx, line in enumerate(lines):
        if "for variant in" in line:
            variants = lowercase_names(line) or variants
        if has_assignment(line, "prefix"):
            # model-name prefixes are "" or end in '_' ("draft_"); drop
            # the condition's other literals ("main")
            got = [s for s in lowercase_names(line)
                   if s == "" or s.endswith("_")]
            if got:
                prefixes = got
        if "for wname" in line:
            wnames = lowercase_names(line) or wnames
        if "for tname" in line:
            block, j = line, idx
            while not block.rstrip().endswith(":") and j + 1 < len(lines):
                j += 1
                block += lines[j]
            tnames = [s for s in lowercase_names(block)
                      if exec_name_ref(s) == ("exact", s)]
        v = int_after(line, "FORMAT_VERSION =")
        if v is not None:
            out.format_version = v
            out.fv_line = idx + 1
        if out.format_version is None:
            v = int_after(line, '"format_version":')
            if v is not None:
                out.format_version = v
                out.fv_line = idx + 1

    subst = {"variant": variants, "prefix": prefixes, "wname": wnames}

    def expand(template, lineno):
        names = [""]
        pos = 0
        while pos < len(template):
            b = template.find("{", pos)
            if b == -1:
                names = [n + template[pos:] for n in names]
                break
            e = template.find("}", b)
            var = template[b + 1:e]
            vals = subst.get(var)
            if not vals:
                out.errors.append(Finding(
                    rel, lineno, "abi-drift",
                    f"cannot resolve placeholder '{{{var}}}' in an AOT "
                    "entry-point name"))
                return []
            names = [n + template[pos:b] + v for n in names for v in vals]
            pos = e + 1
        return names

    for idx, line in enumerate(lines):
        stripped = line.lstrip()
        if not stripped.startswith("add("):
            continue
        lineno = idx + 1
        call = balanced_call(lines, idx, line.index("add(") + 3)
        inner = call[1:-1]
        first = inner.split(",", 1)[0].strip()
        if first.startswith('f"') and first.endswith('"'):
            names = expand(first[2:-1], lineno)
        elif first.startswith('"') and first.endswith('"'):
            names = [first[1:-1]]
        elif first == "tname":
            names = list(tnames)
            if not names:
                out.errors.append(Finding(
                    rel, lineno, "abi-drift",
                    "cannot resolve 'tname' entry-point names"))
        else:
            out.errors.append(Finding(
                rel, lineno, "abi-drift",
                f"cannot resolve entry-point name expression '{first}'"))
            names = []
        # arity: count of _spec() lowering args vs declared input _sig()s
        groups = []
        depth = 0
        gstart = None
        for p, ch in enumerate(inner):
            if ch == "[" and depth == 0:
                gstart = p
            if ch in "([":
                depth += 1
            elif ch in ")]":
                depth -= 1
                if ch == "]" and depth == 0:
                    groups.append(inner[gstart:p + 1])
        arity_ok = True
        if len(groups) >= 2:
            n_spec = count_occurrences(groups[0], "_spec(")
            n_sig = count_occurrences(groups[1], "_sig(")
            arity_ok = n_spec == n_sig
            if not arity_ok:
                out.errors.append(Finding(
                    rel, lineno, "abi-drift",
                    f"entry point declares {n_spec} lowering args but "
                    f"{n_sig} input signatures"))
        for nm in names:
            out.names.setdefault(nm, (lineno, arity_ok))

    in_meta = in_const = False
    for idx, line in enumerate(lines):
        if line.lstrip().startswith("EXEC_META") and "{" in line:
            in_meta = True
            continue
        if in_meta:
            if line.strip() == "}":
                in_meta = False
                continue
            keys = quoted_keys(line)
            if keys and line.lstrip().startswith('"'):
                out.exec_meta.append((keys[0], idx + 1))
        if '"constants": {' in line:
            in_const = True
            continue
        if in_const:
            if line.strip().startswith("}"):
                in_const = False
                continue
            out.constants.extend(quoted_keys(line))
    return out


def parse_manifest_rs(text):
    """(version_range, [(constants_key, line)]) from manifest.rs, skipping
    cfg(test) code."""
    lines = strip_rust(text)
    vrange = None
    vline = 0
    keys = []
    for idx, ln in enumerate(lines):
        if ln.in_test:
            continue
        k = ln.code.find(").contains(&version)")
        if k != -1:
            a = ln.code.rfind("(", 0, k)
            if a != -1:
                lo_hi = ln.code[a + 1:k].split("..=")
                if len(lo_hi) == 2 and lo_hi[0].isdigit() \
                        and lo_hi[1].isdigit():
                    vrange = (int(lo_hi[0]), int(lo_hi[1]))
                    vline = idx + 1
        # string contents are stripped out of code; pair get_usize/get_i32
        # calls on `c` with the string literals that start on the line
        ncalls = count_occurrences(ln.code, 'get_usize(c, "') \
            + count_occurrences(ln.code, 'get_i32(c, "')
        for s in ln.strings[:ncalls]:
            keys.append((s, idx + 1))
    return vrange, vline, keys


def rust_name_refs(rel, text):
    """Exec-name-shaped string literals in non-test code."""
    refs = []
    for idx, ln in enumerate(strip_rust(text)):
        if ln.in_test:
            continue
        for s in ln.strings:
            r = exec_name_ref(s)
            if r:
                refs.append((r, rel, idx + 1, s))
    return refs


def abi_check(root, spec_names=None, spec_fv=None):
    findings = []
    aot_rel = "python/compile/aot.py"
    aot_path = os.path.join(root, aot_rel)
    if not os.path.exists(aot_path):
        return findings
    specs = parse_aot(aot_rel, open(aot_path).read())
    findings.extend(specs.errors)
    built = set(spec_names) if spec_names is not None else set(specs.names)
    fv = spec_fv if spec_fv is not None else specs.format_version

    for key, lineno in specs.exec_meta:
        if key not in built:
            findings.append(Finding(
                aot_rel, lineno, "abi-drift",
                f"EXEC_META key '{key}' does not match any built entry "
                "point"))

    man_rel = "rust/src/runtime/manifest.rs"
    man_path = os.path.join(root, man_rel)
    if os.path.exists(man_path):
        man_text = open(man_path).read()
        vrange, vline, keys = parse_manifest_rs(man_text)
        if vrange and fv is not None and \
                not (vrange[0] <= fv <= vrange[1]):
            findings.append(Finding(
                man_rel, vline, "abi-drift",
                f"manifest.rs accepts format_version {vrange[0]}..="
                f"{vrange[1]} but python/compile emits {fv}"))
        cset = set(specs.constants)
        for key, lineno in keys:
            if cset and key not in cset:
                findings.append(Finding(
                    man_rel, lineno, "abi-drift",
                    f"manifest.rs reads constant '{key}' that "
                    "python/compile does not emit"))

    for rf in ABI_RUST_FILES:
        path = os.path.join(root, rf)
        if not os.path.exists(path):
            continue
        for (kind, val), frel, lineno, lit in rust_name_refs(
                rf, open(path).read()):
            if kind == "exact" and val not in built:
                findings.append(Finding(
                    frel, lineno, "abi-drift",
                    f"exec name '{val}' is not built by "
                    "python/compile/aot.py"))
            elif kind == "prefix" and \
                    not any(n.startswith(val) for n in built):
                findings.append(Finding(
                    frel, lineno, "abi-drift",
                    f"no built entry point matches exec-name prefix "
                    f"'{val}'"))
    return findings


# ----------------------------------------------------------- tree walk

def walk(root):
    files = []
    for sub in ("rust/src", "rust/benches", "rust/tests"):
        base = os.path.join(root, sub)
        for dirpath, _dirs, names in sorted(os.walk(base)):
            for nm in sorted(names):
                if nm.endswith(".rs"):
                    files.append(os.path.relpath(
                        os.path.join(dirpath, nm), root).replace(os.sep, "/"))
    return sorted(files)


def read_spec_json(text):
    """Minimal reader for `aot.py --dump-specs` output (one entry per
    line, not a general JSON parser). Returns (names, format_version)."""
    names = []
    fv = None
    for line in text.split("\n"):
        if fv is None:
            fv = int_after(line, '"format_version":')
        i = 0
        while True:
            k = line.find('"name":', i)
            if k == -1:
                break
            rest = line[k + 7:]
            vals = quoted_strings(rest)
            if vals:
                names.append(vals[0][0])
            i = k + 7
    return names, fv


def run(root, spec_names=None, spec_fv=None):
    findings = []
    for rel in walk(root):
        findings.extend(scan_rust_file(rel, open(os.path.join(root, rel)).read()))
    findings.extend(abi_check(root, spec_names, spec_fv))
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return findings


# ----------------------------------------------------------- baseline

def counts_of(findings):
    counts = {}
    for f in findings:
        counts[(f.file, f.rule)] = counts.get((f.file, f.rule), 0) + 1
    return counts


def write_baseline(path, counts):
    with open(path, "w") as fh:
        fh.write(
            "# d3lint baseline: accepted pre-existing violations, counted\n"
            "# per (file, rule). CI ratchets against this file — new\n"
            "# violations fail, and fixing violations requires shrinking\n"
            "# the matching count here (a stale baseline also fails).\n"
            "# Regenerate: cargo run -p d3lint -- --write-baseline\n"
            "\n[counts]\n")
        for (file, rule) in sorted(counts):
            fh.write(f'"{file}:{rule}" = {counts[(file, rule)]}\n')


def read_baseline(path):
    counts = {}
    for raw in open(path):
        line = raw.strip()
        if not line or line.startswith("#") or line == "[counts]":
            continue
        if not line.startswith('"'):
            continue
        b = line.find('"', 1)
        if b == -1:
            continue
        key = line[1:b]
        val = int_after(line, '" =')
        if val is None or ":" not in key:
            continue
        file, rule = key.rsplit(":", 1)
        counts[(file, rule)] = val
    return counts


def main():
    root = os.path.normpath(os.path.join(os.path.dirname(__file__),
                                         "..", "..", ".."))
    args = sys.argv[1:]
    spec_names = spec_fv = None
    if "--abi-spec" in args:
        spec_path = args[args.index("--abi-spec") + 1]
        spec_names, spec_fv = read_spec_json(open(spec_path).read())
    findings = run(root, spec_names, spec_fv)
    baseline_path = os.path.join(root, "lint-baseline.toml")
    if "--write-baseline" in args:
        write_baseline(baseline_path, counts_of(findings))
        print(f"wrote {baseline_path} ({len(findings)} findings)")
        return 0
    if "--check-baseline" in args:
        base = read_baseline(baseline_path)
        cur = counts_of(findings)
        bad = 0
        for key in sorted(set(base) | set(cur)):
            b, c = base.get(key, 0), cur.get(key, 0)
            if c > b:
                print(f"{key[0]}: {c - b} new '{key[1]}' violation(s) "
                      f"(baseline {b}, current {c})")
                bad += 1
            elif c < b:
                print(f"{key[0]}: stale baseline for '{key[1]}' "
                      f"(baseline {b}, current {c}) — shrink it")
                bad += 1
        print(f"{len(findings)} findings, {len(base)} baseline keys, "
              f"{bad} drift(s)")
        return 1 if bad else 0
    for f in findings:
        print(f.render())
    print(f"{len(findings)} findings")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
