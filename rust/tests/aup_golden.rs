//! Golden-file pins for the AUP metric (paper §2).
//!
//! `rust/tests/golden/aup_golden.json` fixes AUP values on a small
//! accuracy/parallelism grid (computed independently of this crate), so
//! scheduler or sweep changes can't silently shift reported AUP. If the
//! metric definition deliberately changes, regenerate the golden file and
//! say so in the PR.

use d3llm::metrics::aup::{aup_from_points, Point};
use d3llm::util::json;

fn load_cases() -> Vec<(String, f64, Option<f64>, Vec<Point>, f64)> {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/golden/aup_golden.json"
    );
    let text = std::fs::read_to_string(path).expect("golden file");
    let j = json::parse(&text).expect("golden json");
    j.get("cases")
        .and_then(|c| c.as_arr())
        .expect("cases array")
        .iter()
        .map(|c| {
            let name = c.get("name").unwrap().as_str().unwrap().to_string();
            let alpha = c.get("alpha").unwrap().as_f64().unwrap();
            let y_max = c.get("y_max").and_then(|v| v.as_f64());
            let points: Vec<Point> = c
                .get("points")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| {
                    let a = p.as_arr().unwrap();
                    Point {
                        rho: a[0].as_f64().unwrap(),
                        acc: a[1].as_f64().unwrap(),
                    }
                })
                .collect();
            let expect = c.get("expect").unwrap().as_f64().unwrap();
            (name, alpha, y_max, points, expect)
        })
        .collect()
}

#[test]
fn aup_matches_golden_values() {
    let cases = load_cases();
    assert!(cases.len() >= 8, "golden file lost cases");
    for (name, alpha, y_max, points, expect) in cases {
        let got = aup_from_points(&points, alpha, y_max);
        let tol = 1e-6 * expect.abs().max(1.0);
        assert!(
            (got - expect).abs() <= tol,
            "AUP drift on `{name}`: got {got}, golden {expect}"
        );
    }
}

#[test]
fn aup_golden_is_input_order_invariant() {
    // the pinned values must not depend on sweep/scheduler output order
    for (name, alpha, y_max, points, expect) in load_cases() {
        let mut reversed = points.clone();
        reversed.reverse();
        let got = aup_from_points(&reversed, alpha, y_max);
        let tol = 1e-6 * expect.abs().max(1.0);
        assert!(
            (got - expect).abs() <= tol,
            "order-dependent AUP on `{name}`: got {got}, golden {expect}"
        );
    }
}
