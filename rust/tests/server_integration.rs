//! Integration: the serving coordinator end to end over a real TCP socket
//! — request routing, priority batching, stats, malformed input, shutdown.
//! Needs artifacts; builds a throwaway random-init checkpoint.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use d3llm::coordinator::{self, ServerCfg};
use d3llm::decode::Strategy;
use d3llm::model::ParamStore;
use d3llm::runtime::Manifest;
use d3llm::util::json;

fn request(addr: &str, line: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    writeln!(stream, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    resp.trim().to_string()
}

#[test]
fn server_serves_generates_and_shuts_down() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing");
        return;
    }
    // throwaway checkpoint the server can load
    let manifest = Manifest::load("artifacts").unwrap();
    let params = ParamStore::init(&manifest.models["main"], 11);
    std::fs::create_dir_all("checkpoints").unwrap();
    params.save("checkpoints/test-server.ckpt").unwrap();

    let port = 7891u16;
    let cfg = ServerCfg {
        host: "127.0.0.1".into(),
        port,
        ckpt: "test-server".into(),
        strategy: Strategy::FastDllm,
        variant: "xla".into(),
        max_queue: 16,
        max_concurrent_sessions: 4,
        // paged KV serving on a small budget: exercises pool admission,
        // prefix sharing and page release end to end
        draft: None,
        kv_budget_mb: 64,
        slo_round_width: 0,
        workers: 1,
        spill_after_rounds: 0,
        adaptive: Default::default(),
        decode: None,
    };
    let handle = std::thread::spawn(move || {
        let _ = coordinator::serve(cfg);
    });
    let addr = format!("127.0.0.1:{port}");
    // wait for readiness
    let mut up = false;
    for _ in 0..300 {
        if TcpStream::connect(&addr).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(up, "server did not come up");

    // ---- malformed request -> structured error
    let resp = request(&addr, "this is not json");
    let j = json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));

    // ---- generate
    let resp = request(
        &addr,
        r#"{"id":"g1","prompt":"Q EVAL 3 + 4","gen_len":32}"#,
    );
    let j = json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp}");
    assert_eq!(j.get("id").and_then(|v| v.as_str()), Some("g1"));
    assert!(j.get("gen_tokens").and_then(|v| v.as_usize()).unwrap() > 0);
    assert!(j.get("tpf").and_then(|v| v.as_f64()).unwrap() > 0.0);

    // ---- SLO-tagged generate: class echoed back, no miss on an idle
    //      server with a generous budget
    let resp = request(
        &addr,
        r#"{"id":"g-slo","prompt":"Q EVAL 1 + 1","gen_len":32,"slo":"interactive","deadline_ms":60000}"#,
    );
    let j = json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp}");
    assert_eq!(j.get("slo").and_then(|v| v.as_str()), Some("interactive"));
    assert_eq!(j.get("deadline_missed").and_then(|v| v.as_bool()),
               Some(false));

    // ---- unknown token in prompt -> per-request error, server survives
    let resp = request(&addr, r#"{"id":"g2","prompt":"BOGUSWORD"}"#);
    let j = json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));

    // ---- malformed-request regression battery: every line must come
    //      back as a structured `ok:false` reply (never a dropped
    //      connection or a dead replica). Covers the serving-path panic
    //      burn-down in coordinator/{mod,router,protocol}.rs.
    for bad in [
        // unknown per-request strategy -> "bad strategy" error reply
        r#"{"id":"bs","prompt":"Q EVAL 1 + 1","strategy":"warp-drive"}"#,
        // unknown command verb
        r#"{"cmd":"bogus"}"#,
        // generate line missing required fields
        r#"{"id":"noprompt"}"#,
        r#"{"prompt":"Q EVAL 1 + 1"}"#,
        // unknown SLO class
        r#"{"id":"bslo","prompt":"Q EVAL 1 + 1","slo":"hyperspeed"}"#,
        // truncated JSON
        r#"{"id":"trunc","prompt":"Q EVAL"#,
    ] {
        let resp = request(&addr, bad);
        let j = json::parse(&resp)
            .unwrap_or_else(|e| panic!("unparseable reply to {bad}: {e}"));
        assert_eq!(
            j.get("ok").and_then(|v| v.as_bool()),
            Some(false),
            "expected error reply for {bad}, got {resp}"
        );
    }
    // the replica survived the battery: a well-formed request still works
    let resp = request(
        &addr,
        r#"{"id":"after-bad","prompt":"Q EVAL 2 + 2","gen_len":32}"#,
    );
    let j = json::parse(&resp).unwrap();
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp}");
    assert_eq!(j.get("id").and_then(|v| v.as_str()), Some("after-bad"));

    // ---- concurrent requests from multiple clients
    let mut handles = Vec::new();
    for i in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let line = format!(
                r#"{{"id":"c{i}","prompt":"Q EVAL {i} + 2","gen_len":32,"priority":{i}}}"#
            );
            let resp = request(&addr, &line);
            let j = json::parse(&resp).unwrap();
            assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true),
                       "{resp}");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // ---- stats (including the interleaving gauges)
    let resp = request(&addr, r#"{"cmd":"stats"}"#);
    let j = json::parse(&resp).unwrap();
    assert!(j.get("served").and_then(|v| v.as_usize()).unwrap() >= 5);
    assert_eq!(
        j.get("max_concurrent_sessions").and_then(|v| v.as_usize()),
        Some(4)
    );
    assert!(j.get("queue_depth").is_some());
    assert!(j.get("active_sessions").is_some());
    assert!(j.get("sessions").and_then(|v| v.as_arr()).is_some());
    // per-class SLO counters: the tagged request above landed in
    // `interactive`, nothing was shed on an idle server
    let slo = j.get("slo").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(slo.len(), 3);
    assert_eq!(slo[0].get("class").and_then(|v| v.as_str()),
               Some("interactive"));
    assert!(slo[0].get("served").and_then(|v| v.as_usize()).unwrap() >= 1);
    assert_eq!(j.get("shed").and_then(|v| v.as_usize()), Some(0));
    // fleet fields present even for a single worker: same pinned names
    // carry the (degenerate) fleet sums plus the per-replica breakdown
    assert_eq!(j.get("workers").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(j.get("replicas_alive").and_then(|v| v.as_usize()), Some(1));
    let reps = j.get("replicas").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(reps.len(), 1);
    assert_eq!(reps[0].get("replica").and_then(|v| v.as_usize()), Some(0));
    assert_eq!(reps[0].get("alive").and_then(|v| v.as_bool()), Some(true));
    assert!(reps[0].get("served").and_then(|v| v.as_usize()).unwrap() >= 5);

    // ---- shutdown
    let _ = request(&addr, r#"{"cmd":"shutdown"}"#);
    for _ in 0..100 {
        if handle.is_finished() {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(handle.is_finished(), "server did not shut down");
}
