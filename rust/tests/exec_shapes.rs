//! Regression pins for `model::exec::decode_window`'s argument assembly.
//! No real artifacts needed: the vendored offline `xla` stub validates
//! shapes faithfully and only refuses the final execute, so everything up
//! to (and excluding) graph execution is exercised for real — including
//! the paged-native staging path on `Engine`.
//!
//! The headline pin: the valid-mask argument is validated against the
//! *manifest* shape on both the buffered and the literal call path. The
//! seed built it from `cache.capacity()` on one path only, so a pool
//! whose span capacity diverged from the executable's lowered `S_max`
//! failed (or silently passed a wrong-length mask) depending on which
//! path served the call.

use std::path::PathBuf;

use d3llm::model::exec;
use d3llm::model::kv_pool::{KvPoolCfg, PagedKv, SharedKvPool};
use d3llm::model::{KvCache, KvView};
use d3llm::runtime::Engine;

const MANIFEST: &str = r#"{
  "format_version": 1,
  "constants": {"vocab":128,"pad_id":0,"mask_id":1,"eos_id":2,"bos_id":3,
    "sep_id":4,"s_max":16,"s_train":8,"gen_max":8,"gen_train":4,
    "window":2,"block":2,"verify_w":2,"b_train":1,"b_traj":1,
    "rank_never":100000},
  "models": {"main": {"name":"main","d_model":4,"n_layers":1,"n_heads":2,
    "d_head":2,"d_ff":8,"vocab":128,"s_max":16,"d_kv":4,
    "total_params":4,
    "param_layout":[
      {"name":"w","shape":[4],"offset":0,"size":4,"init":"normal"}]}},
  "executables": [{"name":"decode_xla","file":"decode_xla.hlo.txt",
    "model":"main",
    "inputs":[
      {"name":"params","shape":[4],"dtype":"f32"},
      {"name":"win_tokens","shape":[2],"dtype":"i32"},
      {"name":"win_pos","shape":[2],"dtype":"i32"},
      {"name":"win_valid","shape":[2],"dtype":"f32"},
      {"name":"kcache","shape":[1,16,4],"dtype":"f32"},
      {"name":"vcache","shape":[1,16,4],"dtype":"f32"},
      {"name":"cvalid","shape":[16],"dtype":"f32"}],
    "outputs":[
      {"name":"argmax","shape":[2],"dtype":"i32"},
      {"name":"conf","shape":[2],"dtype":"f32"},
      {"name":"entropy","shape":[2],"dtype":"f32"},
      {"name":"k_win","shape":[1,2,4],"dtype":"f32"},
      {"name":"v_win","shape":[1,2,4],"dtype":"f32"}]}]
}"#;

/// v2 manifest: the same dense `decode_xla` plus its paged lowering
/// (`decode_paged_xla`, page-table ABI 2 rows x 8 pages = S_max 16).
const MANIFEST_V2: &str = r#"{
  "format_version": 2,
  "constants": {"vocab":128,"pad_id":0,"mask_id":1,"eos_id":2,"bos_id":3,
    "sep_id":4,"s_max":16,"s_train":8,"gen_max":8,"gen_train":4,
    "window":2,"block":2,"verify_w":2,"b_train":1,"b_traj":1,
    "rank_never":100000},
  "models": {"main": {"name":"main","d_model":4,"n_layers":1,"n_heads":2,
    "d_head":2,"d_ff":8,"vocab":128,"s_max":16,"d_kv":4,
    "total_params":4,
    "param_layout":[
      {"name":"w","shape":[4],"offset":0,"size":4,"init":"normal"}]}},
  "executables": [{"name":"decode_xla","file":"decode_xla.hlo.txt",
    "model":"main",
    "inputs":[
      {"name":"params","shape":[4],"dtype":"f32"},
      {"name":"win_tokens","shape":[2],"dtype":"i32"},
      {"name":"win_pos","shape":[2],"dtype":"i32"},
      {"name":"win_valid","shape":[2],"dtype":"f32"},
      {"name":"kcache","shape":[1,16,4],"dtype":"f32"},
      {"name":"vcache","shape":[1,16,4],"dtype":"f32"},
      {"name":"cvalid","shape":[16],"dtype":"f32"}],
    "outputs":[
      {"name":"argmax","shape":[2],"dtype":"i32"},
      {"name":"conf","shape":[2],"dtype":"f32"},
      {"name":"entropy","shape":[2],"dtype":"f32"},
      {"name":"k_win","shape":[1,2,4],"dtype":"f32"},
      {"name":"v_win","shape":[1,2,4],"dtype":"f32"}]},
   {"name":"decode_paged_xla","file":"decode_paged_xla.hlo.txt",
    "model":"main","paged":{"page_rows":2,"max_pages":8},
    "inputs":[
      {"name":"params","shape":[4],"dtype":"f32"},
      {"name":"win_tokens","shape":[2],"dtype":"i32"},
      {"name":"win_pos","shape":[2],"dtype":"i32"},
      {"name":"win_valid","shape":[2],"dtype":"f32"},
      {"name":"k_pages","shape":[1,8,2,4],"dtype":"f32"},
      {"name":"v_pages","shape":[1,8,2,4],"dtype":"f32"},
      {"name":"page_index","shape":[8],"dtype":"i32"},
      {"name":"page_valid","shape":[8],"dtype":"i32"}],
    "outputs":[
      {"name":"argmax","shape":[2],"dtype":"i32"},
      {"name":"conf","shape":[2],"dtype":"f32"},
      {"name":"entropy","shape":[2],"dtype":"f32"},
      {"name":"k_win","shape":[1,2,4],"dtype":"f32"},
      {"name":"v_win","shape":[1,2,4],"dtype":"f32"}]}]
}"#;

fn artifacts_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("d3llm_exec_shapes_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("decode_xla.hlo.txt"), "HloModule decode_xla\n")
        .unwrap();
    dir
}

fn artifacts_dir_v2(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("d3llm_exec_shapes_v2_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST_V2).unwrap();
    std::fs::write(dir.join("decode_xla.hlo.txt"), "HloModule decode_xla\n")
        .unwrap();
    std::fs::write(dir.join("decode_paged_xla.hlo.txt"),
                   "HloModule decode_paged_xla\n")
        .unwrap();
    dir
}

#[test]
fn capacity_mismatch_fails_identically_on_both_paths() {
    let dir = artifacts_dir("mismatch");
    let eng = Engine::load(&dir).unwrap();
    let params = vec![0.0f32; 4];
    // capacity 8 != the executable's lowered S_max 16
    let cache = KvCache::new(1, 8, 4);
    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];

    let mut errs = Vec::new();
    for buffered in [true, false] {
        eng.set_buffered(buffered);
        let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                    &pos, &valid, &cache)
            .unwrap_err()
            .to_string();
        assert!(e.contains("capacity 8") && e.contains("16"),
                "buffered={buffered}: unclear mismatch error: {e}");
        errs.push(e);
    }
    assert_eq!(errs[0], errs[1],
               "both call paths must reject the mismatch identically");
}

#[test]
fn matching_capacity_passes_validation_on_both_paths() {
    let dir = artifacts_dir("match");
    let eng = Engine::load(&dir).unwrap();
    let params = vec![0.0f32; 4];
    let cache = KvCache::new(1, 16, 4);
    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];

    for buffered in [true, false] {
        eng.set_buffered(buffered);
        let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                    &pos, &valid, &cache)
            .unwrap_err()
            .to_string();
        // every argument (valid mask included) validated cleanly on both
        // paths; only the offline stub's execute may refuse
        assert!(e.contains("offline xla stub cannot execute"),
                "buffered={buffered}: validation should pass, got: {e}");
    }
}

#[test]
fn paged_views_stage_through_the_engine_scratch() {
    let dir = artifacts_dir("paged");
    let eng = Engine::load(&dir).unwrap();
    let params = vec![0.0f32; 4];
    let pool = SharedKvPool::new(KvPoolCfg {
        layers: 1,
        d_kv: 4,
        s_max: 16,
        page_rows: 2,
        budget_bytes: 1 << 16,
    });
    let mut view = PagedKv::admit(&pool, &[], "t", 0, 16, false).unwrap();
    let full: Vec<f32> = (0..64).map(|i| i as f32).collect(); // [1,16,4]
    view.install_full(&full, &full, 0, 6).unwrap();

    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];
    let e = exec::decode_window(&eng, "decode_xla", &params, &toks, &pos,
                                &valid, &view)
        .unwrap_err()
        .to_string();
    assert!(e.contains("offline xla stub cannot execute"),
            "paged staging must validate cleanly up to execution: {e}");
    let st = eng.kv_stage_stats();
    assert_eq!(st.stage_calls, 1);
    assert_eq!(st.pages_copied as usize, 3, "rows 0..6 live on 3 pages");

    // an unchanged view re-stages zero pages on the next forward
    let _ = exec::decode_window(&eng, "decode_xla", &params, &toks, &pos,
                                &valid, &view);
    let st = eng.kv_stage_stats();
    assert_eq!(st.stage_calls, 2);
    assert_eq!(st.pages_copied, 3);
    assert_eq!(st.pages_reused, 3);
    // the staged image equals the reference dense gather bit for bit
    let stage = eng.kv_stage();
    assert_eq!(stage.k.as_slice(), view.k_dense().as_ref());
    assert_eq!(stage.valid.as_slice(), view.valid_dense().as_ref());
}

// ------------------------------------------------- v2: paged executables

#[test]
fn paged_executable_serves_both_views_without_staging() {
    let dir = artifacts_dir_v2("serve");
    let eng = Engine::load(&dir).unwrap();
    let params = vec![0.0f32; 4];
    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];

    let pool = SharedKvPool::new(KvPoolCfg {
        layers: 1,
        d_kv: 4,
        s_max: 16,
        page_rows: 2,
        budget_bytes: 1 << 16,
    });
    let mut paged = PagedKv::admit(&pool, &[], "t", 0, 16, false).unwrap();
    let full: Vec<f32> = (0..64).map(|i| i as f32).collect(); // [1,16,4]
    paged.install_full(&full, &full, 0, 6).unwrap();
    let mut dense = KvCache::new(1, 16, 4);
    KvView::install_full(&mut dense, &full, &full, 0, 6).unwrap();

    let views: [&dyn KvView; 2] = [&paged, &dense];
    for view in views {
        for buffered in [true, false] {
            eng.set_buffered(buffered);
            let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                        &pos, &valid, view)
                .unwrap_err()
                .to_string();
            // routed to the paged lowering, validated cleanly up to the
            // offline stub's execute refusal
            assert!(e.contains("decode_paged_xla"),
                    "buffered={buffered}: expected the paged lowering to \
                     serve the call, got: {e}");
            assert!(e.contains("offline xla stub cannot execute"),
                    "buffered={buffered}: validation should pass: {e}");
        }
    }
    // the paged-native path never touches the dense staging scratch
    let st = eng.kv_stage_stats();
    assert_eq!(st.stage_calls, 0, "paged path must not stage");
    assert_eq!(st.bytes_copied, 0, "paged path must stage 0 bytes");
}

#[test]
fn abi_page_size_mismatch_falls_back_to_the_staged_path() {
    let dir = artifacts_dir_v2("fallback");
    let eng = Engine::load(&dir).unwrap();
    let params = vec![0.0f32; 4];
    // pool pages of 4 rows != the lowered ABI's 2 rows per entry
    let pool = SharedKvPool::new(KvPoolCfg {
        layers: 1,
        d_kv: 4,
        s_max: 16,
        page_rows: 4,
        budget_bytes: 1 << 16,
    });
    let mut view = PagedKv::admit(&pool, &[], "t", 0, 16, false).unwrap();
    let full: Vec<f32> = (0..64).map(|i| i as f32).collect();
    view.install_full(&full, &full, 0, 6).unwrap();

    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];
    let mut errs = Vec::new();
    for buffered in [true, false] {
        eng.set_buffered(buffered);
        let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                    &pos, &valid, &view)
            .unwrap_err()
            .to_string();
        assert!(e.contains("`decode_xla`"),
                "buffered={buffered}: must fall back to the dense \
                 lowering, got: {e}");
        assert!(e.contains("offline xla stub cannot execute"),
                "buffered={buffered}: fallback must validate cleanly: {e}");
        // the stub tags the buffered execute; normalize before comparing
        errs.push(e.replace(" (buffered)", ""));
    }
    assert_eq!(errs[0], errs[1], "fallback must be path-deterministic");
    // the fallback staged: one stage per attempted forward
    assert_eq!(eng.kv_stage_stats().stage_calls, 2);
}

#[test]
fn v2_capacity_mismatch_fails_identically_on_both_paths() {
    let dir = artifacts_dir_v2("cap");
    let eng = Engine::load(&dir).unwrap();
    let params = vec![0.0f32; 4];
    // capacity 8 != page_rows * max_pages (= 16): the paged gate must
    // decline and the dense validation must produce the same pinned
    // error on the buffered and the literal path
    let cache = KvCache::new(1, 8, 4);
    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];
    let mut errs = Vec::new();
    for buffered in [true, false] {
        eng.set_buffered(buffered);
        let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                    &pos, &valid, &cache)
            .unwrap_err()
            .to_string();
        assert!(e.contains("capacity 8") && e.contains("16"),
                "buffered={buffered}: unclear mismatch error: {e}");
        errs.push(e);
    }
    assert_eq!(errs[0], errs[1]);
    assert_eq!(eng.kv_stage_stats().stage_calls, 0);
}

#[test]
fn page_table_packing_compacts_scattered_valid_rows() {
    let pool = SharedKvPool::new(KvPoolCfg {
        layers: 1,
        d_kv: 4,
        s_max: 16,
        page_rows: 2,
        budget_bytes: 1 << 16,
    });
    let mut view = PagedKv::admit(&pool, &[], "t", 0, 16, false).unwrap();
    let full: Vec<f32> = (0..64).map(|i| i as f32).collect(); // [1,16,4]
    view.install_full(&full, &full, 0, 2).unwrap();
    // scattered commits: rows 5 and 8 valid, 4 and 9 not — non-prefix
    // validity inside pages (2,*) and (4,*)
    let kwin: Vec<f32> = (0..8).map(|i| 100.0 + i as f32).collect();
    view.commit_window_rows(&kwin, &kwin, 2, &[(0, 5), (1, 8)]).unwrap();

    let t = exec::pack_page_table(&view, 2, 8).unwrap();
    assert_eq!(t.rows_packed, 4);
    assert_eq!(t.rows_packed, view.valid_count());
    // entries are (slot, packed-count) pairs over the live pages
    let live: Vec<(i32, i32)> = t
        .page_index
        .iter()
        .zip(&t.page_valid)
        .filter(|(&ix, _)| ix >= 0)
        .map(|(&ix, &n)| (ix, n))
        .collect();
    assert_eq!(live, [(0, 2), (2, 1), (4, 1)],
               "slot 0 full, slots 2/4 hold one scattered row each");
    // the scattered rows are compacted to the FRONT of their entries:
    // row 5 (odd row of slot 2) sits at packed offset 0 of its entry
    let d = 4;
    let entry = |j: usize| &t.k_pages[(j * 2) * d..(j * 2) * d + d];
    // entry order follows for_each_page's ascending slot order: entry 1
    // is slot 2 (row 5 = kwin window offset 0), entry 2 is slot 4
    assert_eq!(entry(1), &kwin[0..4]);
    assert_eq!(entry(2), &kwin[4..8]);
    // a dense cache with the same contents packs the same row *set*
    // (identity slots, so entry layout differs but totals match)
    let mut dense = KvCache::new(1, 16, 4);
    KvView::install_full(&mut dense, &full, &full, 0, 2).unwrap();
    KvView::commit_window_rows(&mut dense, &kwin, &kwin, 2,
                               &[(0, 5), (1, 8)])
        .unwrap();
    let td = exec::pack_page_table(&dense, 2, 8).unwrap();
    assert_eq!(td.rows_packed, 4);
    assert_eq!(td.page_valid.iter().sum::<i32>(),
               t.page_valid.iter().sum::<i32>());
}
