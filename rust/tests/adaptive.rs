//! Adaptive parallelism controller — serving-loop pins over the
//! SimBackend (no artifacts, fully deterministic):
//!
//!   * `--adaptive off` is bit-identical (tokens AND forward counts) to
//!     the static path for every strategy, even with the controller wired
//!     into the scheduling loop exactly like `run_replica` wires it;
//!   * an explicit budget pinned at the static operating point
//!     (base threshold, uncapped commits, uncapped width) is also a
//!     strict no-op — the budgeted plan/apply path degrades exactly to
//!     the static one;
//!   * `load` mode is a pure function of the observed load trace: the
//!     same virtual-clock trace yields the same budget sequence, the
//!     same gauges, and the same tokens, run to run;
//!   * the accuracy floor is hard: under adversarial load swings (and
//!     adversarially misconfigured floors) the emitted thresholds never
//!     cross the per-metric bound.

use std::collections::HashMap;

use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{self, AdaptiveCfg, AdaptiveController, AdaptiveMode,
                    DecodeCfg, DecodeSession, GenResult, LoadSignal,
                    RoundBudget, SelMetric, SimBackend, Strategy};
use d3llm::util::rng::Rng;

fn mk(s: Strategy) -> DecodeCfg {
    let mut c = DecodeCfg::preset(s);
    c.early_stop = false; // sim argmax never emits EOS by default
    c
}

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(8 + k % 5)).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

const ALL_STRATEGIES: [Strategy; 7] = [
    Strategy::Vanilla,
    Strategy::Ar,
    Strategy::Spec,
    Strategy::FastDllm,
    Strategy::D2f,
    Strategy::DParallel,
    Strategy::D3llm,
];

fn gen_len_for(s: Strategy) -> usize {
    match s {
        Strategy::Ar | Strategy::Spec => 32,
        _ => 64,
    }
}

/// `--adaptive off`, wired exactly like the replica loop (observe →
/// set_budgets → step_round each round), must keep every strategy
/// token- and forward-identical to the solo static reference.
#[test]
fn off_mode_is_bit_identical_to_static_for_every_strategy() {
    let seed = 53u64;
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let draft = vec![0.25f32; 8];
    let mut ctrl = AdaptiveController::new(AdaptiveCfg::default());
    assert!(!ctrl.enabled());

    let mut pool: SessionPool<usize> = SessionPool::new();
    for (i, &s) in ALL_STRATEGIES.iter().enumerate() {
        let sess = DecodeSession::with_draft(&sim, mk(s), &prompt_for(i),
                                             gen_len_for(s), Some(&draft))
            .unwrap();
        pool.admit(format!("s{i}"), i, sess);
    }
    let mut results: Vec<Option<GenResult>> =
        (0..ALL_STRATEGIES.len()).map(|_| None).collect();
    while !pool.is_empty() {
        // the serving loop's exact per-round controller sequence
        ctrl.observe(&LoadSignal {
            queue_depth: 17, // any backlog: off mode must ignore it
            active_sessions: pool.len(),
            est_wait_ms: 123.0,
            round_ms: 0.0,
        });
        pool.set_budgets(|dcfg, res| {
            ctrl.budget_for(dcfg.metric, res.mean_commit_entropy())
        });
        for f in pool.step_round(&sim, &params) {
            results[f.tag] = Some(f.result.unwrap());
        }
    }

    let ref_sim = SimBackend::new(seed);
    for (i, &s) in ALL_STRATEGIES.iter().enumerate() {
        let got = results[i].take().unwrap();
        let reference = decode::generate(&ref_sim, &mk(s), &params,
                                         Some(&draft), &prompt_for(i),
                                         gen_len_for(s))
            .unwrap();
        assert_eq!(got.tokens, reference.tokens,
                   "{}: off mode changed the tokens", s.name());
        assert_eq!(got.forwards, reference.forwards,
                   "{}: off mode changed the forward count", s.name());
        assert_eq!(got.rounds, reference.rounds, "{}", s.name());
    }
    // the controller stayed inert the whole run
    assert_eq!(ctrl.pressure(), 0.0);
    assert_eq!(ctrl.gauges.threshold_milli, 0);
    assert_eq!(ctrl.gauges.width_hist.iter().sum::<u64>(), 0);
}

/// A budget frozen at the static operating point (base threshold,
/// uncapped commits and width) must route through the budgeted
/// plan/apply path yet decode bit-identically to no budget at all.
#[test]
fn static_valued_budget_is_a_strict_noop() {
    let seed = 59u64;
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let cfg = mk(Strategy::D3llm);
    let static_budget = RoundBudget {
        entropy_threshold: cfg.metric.threshold(),
        max_unmask: usize::MAX,
        block_width: usize::MAX,
    };

    let mut pool: SessionPool<()> = SessionPool::new();
    pool.admit("b".into(), (),
               DecodeSession::new(&sim, cfg.clone(), &prompt_for(2), 96)
                   .unwrap());
    let mut budgeted = None;
    while !pool.is_empty() {
        pool.set_budgets(|_, _| Some(static_budget));
        for f in pool.step_round(&sim, &params) {
            budgeted = Some(f.result.unwrap());
        }
    }
    let budgeted = budgeted.unwrap();

    let ref_sim = SimBackend::new(seed);
    let reference = decode::generate(&ref_sim, &cfg, &params, None,
                                     &prompt_for(2), 96)
        .unwrap();
    assert_eq!(budgeted.tokens, reference.tokens,
               "a static-valued budget changed the trajectory");
    assert_eq!(budgeted.forwards, reference.forwards);
    assert_eq!(budgeted.rounds, reference.rounds);
}

/// One full `load`-mode run over a fixed virtual load trace: returns the
/// emitted budget sequence, per-request tokens, and the final gauges.
fn run_load_trace(seed: u64, trace: &[usize])
                  -> (Vec<RoundBudget>, HashMap<String, Vec<i32>>,
                      u64, u64, u64) {
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let cfg = mk(Strategy::D3llm);
    let mut ctrl = AdaptiveController::new(AdaptiveCfg {
        mode: AdaptiveMode::Load,
        ..AdaptiveCfg::default()
    });
    let mut pool: SessionPool<()> = SessionPool::new();
    for i in 0..3 {
        pool.admit(format!("r{i}"), (),
                   DecodeSession::new(&sim, cfg.clone(), &prompt_for(i),
                                      64)
                       .unwrap());
    }
    let mut budgets: Vec<RoundBudget> = Vec::new();
    let mut tokens: HashMap<String, Vec<i32>> = HashMap::new();
    let mut round = 0usize;
    while !pool.is_empty() {
        let q = trace[round.min(trace.len() - 1)];
        ctrl.observe(&LoadSignal {
            queue_depth: q,
            active_sessions: pool.len(),
            est_wait_ms: 0.0,
            round_ms: 0.0,
        });
        pool.set_budgets(|dcfg, res| {
            let b = ctrl.budget_for(dcfg.metric, res.mean_commit_entropy());
            if let Some(b) = b {
                budgets.push(b);
            }
            b
        });
        for f in pool.step_round(&sim, &params) {
            tokens.insert(f.id, f.result.unwrap().tokens);
        }
        round += 1;
    }
    let g = &ctrl.gauges;
    (budgets, tokens,
     g.threshold_milli, g.adjust_up + g.adjust_down,
     g.width_hist.iter().sum())
}

/// `load` mode is a pure function of the load trace: identical traces
/// give identical budget sequences, gauges, and tokens, run to run.
#[test]
fn load_mode_is_deterministic_over_a_fixed_trace() {
    // an overload burst that ramps, saturates, then drains
    let trace: Vec<usize> =
        [0, 1, 4, 8, 8, 8, 8, 4, 2, 1, 0, 0].to_vec();
    let a = run_load_trace(61, &trace);
    let b = run_load_trace(61, &trace);
    assert_eq!(a.0, b.0, "budget sequences diverged run-to-run");
    assert_eq!(a.1, b.1, "decoded tokens diverged run-to-run");
    assert_eq!((a.2, a.3, a.4), (b.2, b.3, b.4), "gauges diverged");

    assert!(!a.0.is_empty(), "load mode emitted no budgets");
    // the burst actually moved the dial: some budget left the static
    // base, and none ever crossed the calibrated ceiling
    let base = mk(Strategy::D3llm).metric.threshold();
    let ceiling = AdaptiveCfg::default().entropy_ceiling;
    assert!(a.0.iter().any(|b| b.entropy_threshold > base + 0.05),
            "saturation never raised the threshold");
    assert!(a.0.iter().all(|b| b.entropy_threshold <= ceiling + 1e-6));
    assert!(a.0.iter().all(|b| (1..=8).contains(&b.block_width)));
}

/// Property: under adversarial load swings — and adversarially
/// misconfigured floors — the emitted threshold never crosses the
/// per-metric accuracy bound, the width stays in range, and the
/// pressure stays normalized.
#[test]
fn accuracy_floor_survives_adversarial_load_swings() {
    let mut rng = Rng::new(0xADA_BEEF);
    for case in 0..200 {
        let cfg = AdaptiveCfg {
            mode: AdaptiveMode::Load,
            conf_floor: rng.f32() * 1.2,       // may exceed the base
            entropy_ceiling: rng.f32() * 2.0,  // may undercut the base
            max_block_width: 1 + rng.usize(6),
            max_unmask_cap: rng.usize(4),
            backlog_full: 1 + rng.usize(8),
            pool_full: rng.usize(9), // 0 disables the occupancy term
            wait_full_ms: if rng.bool(0.5) { 200.0 } else { 0.0 },
            round_full_ms: if rng.bool(0.5) { 100.0 } else { 0.0 },
            alpha: 0.05 + 0.9 * rng.f64(),
        };
        let mut c = AdaptiveController::new(cfg.clone());
        let base_e = rng.f32() * 1.5;
        let base_c = rng.f32();
        for step in 0..64 {
            c.observe(&LoadSignal {
                queue_depth: rng.usize(32),
                active_sessions: rng.usize(8),
                est_wait_ms: rng.f64() * 1000.0,
                round_ms: rng.f64() * 100.0,
            });
            assert!((0.0..=1.0).contains(&c.pressure()),
                    "case {case} step {step}: pressure left [0,1]");
            let mce = rng.f64() * 5.0; // adversarial quality feedback
            let e = c.budget_for(SelMetric::Entropy(base_e), mce).unwrap();
            assert!(e.entropy_threshold <= cfg.entropy_ceiling + 1e-5,
                    "case {case} step {step}: entropy ceiling crossed \
                     ({} > {})", e.entropy_threshold, cfg.entropy_ceiling);
            assert!(e.entropy_threshold
                        >= base_e.min(cfg.entropy_ceiling) - 1e-5,
                    "case {case} step {step}: drifted below the base");
            let f = c.budget_for(SelMetric::Conf(base_c), mce).unwrap();
            assert!(f.entropy_threshold >= cfg.conf_floor - 1e-5,
                    "case {case} step {step}: conf floor crossed \
                     ({} < {})", f.entropy_threshold, cfg.conf_floor);
            assert!(f.entropy_threshold
                        <= base_c.max(cfg.conf_floor) + 1e-5,
                    "case {case} step {step}: drifted above the base");
            for b in [e, f] {
                assert!(b.block_width >= 1
                            && b.block_width <= cfg.max_block_width.max(1),
                        "case {case} step {step}: width out of range");
                assert!(b.max_unmask >= 1);
            }
        }
    }
}
