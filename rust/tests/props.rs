//! Property-based tests over coordinator invariants (routing, batching,
//! state machines, metric math). No proptest offline — a seeded-RNG
//! harness sweeps many random cases per property with failure reporting.

use d3llm::coordinator::batcher::Batcher;
use d3llm::data::{self, Family};
use d3llm::decode::seq_state::SeqState;
use d3llm::decode::{Backend, SimBackend};
use d3llm::metrics::aup::{aup_from_points, Point};
use d3llm::tokenizer::{Tokenizer, EOS, MASK};
use d3llm::trajectory::{self, build_noisy, Recipe};
use d3llm::util::json;
use d3llm::util::rng::Rng;

/// Run `f` over `cases` seeded cases; panic with the seed on failure.
fn prop(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(1));
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!("property `{name}` failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

// ----------------------------------------------------------------- batcher

#[test]
fn prop_batcher_orders_by_priority_then_fifo() {
    prop("batcher order", 200, |rng| {
        let n = 1 + rng.usize(60);
        let mut b: Batcher<(usize, i64)> = Batcher::new(n);
        let mut items = Vec::new();
        for i in 0..n {
            let pri = rng.range(-3, 4);
            items.push((i, pri));
            assert!(b.push((i, pri), pri));
        }
        let mut popped = Vec::new();
        while let Some(j) = b.pop() {
            popped.push(j.payload);
        }
        assert_eq!(popped.len(), n);
        // sorted by (priority desc, insertion asc)
        for w in popped.windows(2) {
            let (i0, p0) = w[0];
            let (i1, p1) = w[1];
            assert!(p0 > p1 || (p0 == p1 && i0 < i1),
                    "bad order: {:?} then {:?}", w[0], w[1]);
        }
    });
}

#[test]
fn prop_batcher_never_exceeds_capacity() {
    prop("batcher capacity", 100, |rng| {
        let cap = 1 + rng.usize(10);
        let mut b: Batcher<u32> = Batcher::new(cap);
        let mut accepted = 0;
        for i in 0..40u32 {
            if b.push(i, 0) {
                accepted += 1;
            }
            if rng.bool(0.3) {
                if b.pop().is_some() {
                    accepted -= 1;
                }
            }
            assert!(b.len() <= cap);
            assert_eq!(b.len(), accepted);
        }
    });
}

// --------------------------------------------------------------- SeqState

#[test]
fn prop_seq_state_block_accounting() {
    prop("seq block accounting", 200, |rng| {
        let block = 32;
        let n_blocks = 1 + rng.usize(4);
        let gen = block * n_blocks;
        let prompt_len = 1 + rng.usize(100);
        let prompt: Vec<i32> = (0..prompt_len).map(|_| 5).collect();
        let mut st = SeqState::new(&prompt, gen, block, 384);

        // unmask a random subset
        let mut decoded = vec![false; gen];
        for j in 0..gen {
            if rng.bool(0.5) {
                st.tokens[prompt_len + j] = 9;
                decoded[j] = true;
            }
        }
        for b in 0..n_blocks {
            let want =
                decoded[b * block..(b + 1) * block].iter().filter(|&&x| x)
                    .count();
            assert_eq!(st.decoded_in_block(b), want);
            assert_eq!(st.block_complete(b), want == block);
        }
        let first = st.first_incomplete_block();
        match first {
            None => assert!(st.all_decoded()),
            Some(b) => {
                for earlier in 0..b {
                    assert!(st.block_complete(earlier));
                }
                assert!(!st.block_complete(b));
            }
        }
    });
}

#[test]
fn prop_eos_settled_iff_no_mask_before_eos() {
    prop("eos settled", 300, |rng| {
        let prompt: Vec<i32> = vec![5; 4];
        let mut st = SeqState::new(&prompt, 64, 32, 384);
        // random fill
        for j in 0..64 {
            let r = rng.f64();
            st.tokens[4 + j] = if r < 0.4 {
                MASK
            } else if r < 0.5 {
                EOS
            } else {
                9
            };
        }
        let settled = st.eos_settled();
        match st.first_eos() {
            None => assert!(!settled),
            Some(e) => {
                let mask_before =
                    st.tokens[4..e].iter().any(|&t| t == MASK);
                assert_eq!(settled, !mask_before);
                if settled {
                    // output ends exactly at EOS
                    let out = st.output();
                    assert_eq!(*out.last().unwrap(), EOS);
                    assert_eq!(out.len(), e - 4 + 1);
                }
            }
        }
    });
}

// -------------------------------------------------------------------- AUP

#[test]
fn prop_aup_monotone_in_added_lossless_point() {
    // adding a higher-parallelism point at unchanged accuracy never hurts
    prop("aup monotone", 300, |rng| {
        let base_acc = 40.0 + rng.f64() * 50.0;
        let mut pts = vec![Point { rho: 1.0, acc: base_acc }];
        let mut rho = 1.0;
        for _ in 0..rng.usize(5) {
            rho += rng.f64() * 3.0 + 0.1;
            pts.push(Point {
                rho,
                acc: base_acc - rng.f64() * 3.0,
            });
        }
        let before = aup_from_points(&pts, 3.0, None);
        let mut extended = pts.clone();
        extended.push(Point { rho: rho + 2.0, acc: base_acc });
        let after = aup_from_points(&extended, 3.0, None);
        assert!(after >= before - 1e-9, "{before} -> {after}");
    });
}

#[test]
fn prop_aup_bounded_by_unweighted_area() {
    // W(y) <= 1, so AUP <= the plain trapezoid area (same point set)
    prop("aup bounded", 300, |rng| {
        let mut pts = Vec::new();
        let mut rho = 0.5 + rng.f64();
        let top = 50.0 + rng.f64() * 40.0;
        for _ in 0..2 + rng.usize(5) {
            pts.push(Point { rho, acc: top - rng.f64() * 4.0 });
            rho += 0.2 + rng.f64() * 2.0;
        }
        pts.sort_by(|a, b| a.rho.partial_cmp(&b.rho).unwrap());
        let aup = aup_from_points(&pts, 3.0, None);
        let mut area = pts[0].rho * pts[0].acc;
        for w in pts.windows(2) {
            area += (w[1].rho - w[0].rho) * (w[1].acc + w[0].acc) / 2.0;
        }
        assert!(aup <= area + 1e-9, "aup {aup} > area {area}");
    });
}

#[test]
fn prop_aup_alpha_monotone() {
    prop("aup alpha monotone", 200, |rng| {
        let mut pts = Vec::new();
        let mut rho = 1.0;
        let top = 60.0 + rng.f64() * 30.0;
        for i in 0..4 {
            pts.push(Point { rho, acc: top - i as f64 * rng.f64() * 2.0 });
            rho += 1.0 + rng.f64();
        }
        let a1 = aup_from_points(&pts, 1.0, None);
        let a5 = aup_from_points(&pts, 5.0, None);
        assert!(a5 <= a1 + 1e-9);
    });
}

// ------------------------------------- pseudo-trajectory distillation path

fn traj_tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("d3llm_props_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Noisy-sequence construction over teacher ranks extracted on the
/// Backend path: raising the curriculum mask ratio `t` (same sample, same
/// prefix draw) only ever *adds* masks, and the added/retained visibility
/// follows the teacher's rank order — every visible window position
/// outranks (was unmasked before) every masked window position.
#[test]
fn prop_noisy_rank_monotone_across_curriculum_progress() {
    let sim = SimBackend::new(21);
    let c = sim.constants().clone();
    let tk = Tokenizer::new(c.vocab).unwrap();
    let corpus =
        data::train_corpus(&tk, &[(Family::Gsm8k, 1.0)], 6, 9);
    let teacher = vec![0.33f32; 64];
    let dir = traj_tmp_dir("rank_monotone");
    let ranks =
        trajectory::extract_all(&sim, &teacher, &corpus, &dir, "prop")
            .unwrap();

    prop("noisy rank monotone", 60, |rng| {
        let idx = rng.usize(corpus.len());
        let sample = &corpus[idx];
        let k = 16 + rng.usize(17); // window length 16..=32
        let t_lo = rng.f64() * 0.5;
        let t_hi = t_lo + rng.f64() * (1.0 - t_lo);
        let seed = rng.next_u64();
        // same-seeded rngs -> identical internal prefix draw `s`
        let lo = build_noisy(sample, Recipe::PseudoTraj, Some(&ranks[idx]),
                             t_lo, k, &c, &mut Rng::new(seed));
        let hi = build_noisy(sample, Recipe::PseudoTraj, Some(&ranks[idx]),
                             t_hi, k, &c, &mut Rng::new(seed));
        let p = sample.prompt.len();
        let mut masked_lo = 0;
        let mut masked_hi = 0;
        for j in 0..c.gen_train {
            let m_lo = lo.tokens[p + j] == MASK;
            let m_hi = hi.tokens[p + j] == MASK;
            masked_lo += usize::from(m_lo);
            masked_hi += usize::from(m_hi);
            if m_lo {
                assert!(m_hi, "raising t must never unmask position {j}");
            }
            // loss sits exactly on masked gen positions, both levels
            assert_eq!(lo.loss_mask[p + j] > 0.0, m_lo);
            assert_eq!(hi.loss_mask[p + j] > 0.0, m_hi);
        }
        assert!(masked_hi >= masked_lo);
        // teacher-order visibility inside the sampled window (recovered
        // by replaying the builder's single rng draw): every visible
        // window position was unmasked by the teacher before every
        // masked window position
        let s = Rng::new(seed).usize(c.gen_train - k + 1);
        let visible_max = (s..s + k)
            .filter(|&j| hi.tokens[p + j] != MASK)
            .map(|j| ranks[idx][p + j])
            .max();
        let masked_min = (s..s + k)
            .filter(|&j| hi.tokens[p + j] == MASK)
            .map(|j| ranks[idx][p + j])
            .min();
        if let (Some(v), Some(m)) = (visible_max, masked_min) {
            assert!(v < m, "teacher order violated: visible rank {v} >= \
                            masked rank {m}");
        }
    });
}

/// With a left-to-right teacher trajectory the window's masked-token
/// count matches the curriculum schedule exactly:
/// `k - ceil(k * (1 - t))` of the `k` window positions are masked.
#[test]
fn prop_noisy_mask_count_matches_schedule() {
    let sim = SimBackend::new(22);
    let c = sim.constants().clone();
    let tk = Tokenizer::new(c.vocab).unwrap();
    let corpus = data::train_corpus(&tk, &[(Family::Math, 1.0)], 4, 5);
    prop("noisy mask count", 120, |rng| {
        let sample = &corpus[rng.usize(corpus.len())];
        let p = sample.prompt.len();
        let n = c.gen_train;
        // synthetic left-to-right teacher: rank j at gen offset j
        let mut ranks = vec![c.rank_never; c.s_train];
        for j in 0..n {
            ranks[p + j] = j as i32;
        }
        let k = 1 + rng.usize(32);
        let t = rng.f64();
        let seed = rng.next_u64();
        let ex = build_noisy(sample, Recipe::PseudoTraj, Some(&ranks), t, k,
                             &c, &mut Rng::new(seed));
        // replicate the builder's single rng draw to recover the prefix s
        let s = Rng::new(seed).usize(n - k + 1);
        let visible = ((k as f64) * (1.0 - t)).ceil() as usize;
        let masked_in_window = (s..s + k)
            .filter(|&j| ex.tokens[p + j] == MASK)
            .count();
        assert_eq!(masked_in_window, k - visible,
                   "window mask count must follow the schedule \
                    (k={k} t={t:.3} s={s})");
        // everything beyond the window is masked, the prefix is visible
        for j in 0..s {
            assert_ne!(ex.tokens[p + j], MASK);
        }
        for j in s + k..n {
            assert_eq!(ex.tokens[p + j], MASK);
        }
    });
}

/// Extraction is schedule-independent: width-1 (sequential) and width-8
/// (interleaved, batch-coalesced) pooled extraction produce identical
/// ranks, and each sample's gen-region ranks are a permutation.
#[test]
fn prop_extraction_deterministic_across_pool_widths() {
    let sim = SimBackend::new(5);
    let c = sim.constants().clone();
    let tk = Tokenizer::new(c.vocab).unwrap();
    let corpus = data::train_corpus(
        &tk, &[(Family::Gsm8k, 0.5), (Family::HumanEval, 0.5)], 10, 13);
    let teacher = vec![0.7f32; 64];
    let dir = traj_tmp_dir("widths");
    let w1 = trajectory::extract_all_pooled(&sim, &teacher, &corpus, &dir,
                                            "w1", 1, None)
        .unwrap();
    let w8 = trajectory::extract_all_pooled(&sim, &teacher, &corpus, &dir,
                                            "w8", 8, None)
        .unwrap();
    assert_eq!(w1, w8, "width-1 must equal interleaved extraction");
    for (sample, row) in corpus.iter().zip(&w1) {
        let p = sample.prompt.len();
        let mut gen: Vec<i32> = row[p..p + c.gen_train].to_vec();
        gen.sort();
        assert_eq!(gen, (0..c.gen_train as i32).collect::<Vec<_>>());
    }
    // the wide run must actually have batched same-shape rounds
    assert!(sim.max_window_batch() >= 2,
            "interleaved extraction should coalesce window forwards");
}

// ------------------------------------------------------------ data + json

#[test]
fn prop_generated_samples_roundtrip_their_checker() {
    let tk = Tokenizer::new(128).unwrap();
    prop("sample checker", 150, |rng| {
        for &fam in &[Family::Gsm8k, Family::Math, Family::HumanEval,
                      Family::Mbpp] {
            let s = data::generate(&tk, fam, rng);
            assert!(data::check(&tk, &s, &s.response, false));
            // token budget invariants the executables rely on
            assert!(s.prompt.len() + 96 <= 192);
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    prop("json roundtrip", 300, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed, v, "{text}");
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> json::Json {
    use json::Json;
    if depth == 0 {
        return match rng.usize(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::num((rng.range(-1000, 1000) as f64) / 8.0),
            _ => Json::str(format!("s{}", rng.next_u64() % 1000)),
        };
    }
    match rng.usize(6) {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => Json::num(rng.range(-100000, 100000) as f64),
        3 => Json::str("weird \"chars\"\n\t\\ ☃".to_string()),
        4 => Json::arr((0..rng.usize(4)).map(|_| random_json(rng, depth - 1))),
        _ => {
            let n = rng.usize(4);
            Json::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}
