//! Paged KV pool vs. dense cache: the bit-identity, isolation and budget
//! pins behind the pool refactor. Everything runs on the deterministic
//! `SimBackend` (no artifacts): sim KV rows are pure functions of
//! (layer, position, token) and rows are only installed for finalized
//! tokens, so a paged session must reproduce the dense baseline
//! token-for-token and forward-for-forward.

use d3llm::coordinator::scheduler::{run_interleaved, run_interleaved_pooled,
                                    InterleavedRequest, SessionPool};
use d3llm::decode::{Backend, DecodeCfg, DecodeSession, GenResult,
                    SimBackend, Strategy};
use d3llm::model::kv_pool::{is_pool_exhausted, KvPoolCfg, SharedKvPool};

fn pool_for(sim: &SimBackend, pages: usize) -> SharedKvPool {
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let cfg = KvPoolCfg {
        layers: spec.n_layers,
        d_kv: spec.d_kv,
        s_max: c.s_max,
        page_rows: c.block,
        budget_bytes: 0,
    };
    let budget = pages * cfg.page_bytes();
    SharedKvPool::new(KvPoolCfg { budget_bytes: budget, ..cfg })
}

fn prompt(k: usize) -> Vec<i32> {
    (0..14).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

fn run_dense(sim: &SimBackend, cfg: &DecodeCfg, prompt: &[i32],
             gen_len: usize, draft: Option<&[f32]>, params: &[f32])
             -> GenResult {
    let mut s = DecodeSession::with_draft(sim, cfg.clone(), prompt, gen_len,
                                          draft)
        .expect("dense session");
    while !s.step(sim, params).expect("dense step") {}
    s.finish()
}

fn run_pooled(sim: &SimBackend, cfg: &DecodeCfg, prompt: &[i32],
              gen_len: usize, draft: Option<&[f32]>, params: &[f32],
              pool: &SharedKvPool) -> GenResult {
    let mut s = DecodeSession::with_pool(sim, cfg.clone(), prompt, gen_len,
                                         draft, pool)
        .expect("pooled session");
    while !s.step(sim, params).expect("pooled step") {}
    s.finish()
}

/// Every strategy decodes token-for-token identically over a paged view
/// (cold pool: no sharing in play, pure storage-layer equivalence).
#[test]
fn paged_matches_dense_for_every_strategy() {
    let params = vec![0.5f32; 8];
    let draft = vec![0.25f32; 8];
    let sim = SimBackend::new(23);
    for s in Strategy::ALL {
        let mut cfg = DecodeCfg::preset(s);
        cfg.early_stop = false;
        let p = prompt(1);
        let dense = run_dense(&sim, &cfg, &p, 64, Some(&draft), &params);
        let pool = pool_for(&sim, 64);
        let paged =
            run_pooled(&sim, &cfg, &p, 64, Some(&draft), &params, &pool);
        assert_eq!(paged.tokens, dense.tokens, "{} tokens", s.name());
        assert_eq!(paged.forwards, dense.forwards, "{} forwards", s.name());
        assert_eq!(paged.unmasked, dense.unmasked, "{} unmasked", s.name());
        assert_eq!(paged.mix.full_forwards, dense.mix.full_forwards,
                   "{} full forwards", s.name());
        assert_eq!(paged.mix.window_forwards, dense.mix.window_forwards,
                   "{} window forwards", s.name());
        // everything the session held went back to the pool
        let u = pool.usage();
        assert_eq!(u.in_use, 0, "{} leaked pages", s.name());
        assert_eq!(u.reserved, 0, "{} leaked reservation", s.name());
    }
}

/// Early-stop paths (EOS mid-block) stay equivalent too.
#[test]
fn paged_matches_dense_with_early_stop() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(5).with_eos_rate(0.05);
    for s in [Strategy::D3llm, Strategy::FastDllm, Strategy::Ar] {
        let cfg = DecodeCfg::preset(s);
        let p = prompt(2);
        let dense = run_dense(&sim, &cfg, &p, 64, None, &params);
        let pool = pool_for(&sim, 64);
        let paged = run_pooled(&sim, &cfg, &p, 64, None, &params, &pool);
        assert_eq!(paged.tokens, dense.tokens, "{}", s.name());
        assert_eq!(paged.forwards, dense.forwards, "{}", s.name());
    }
}

/// A warm same-prompt session adopts the registered prompt pages, skips
/// its prompt-prefill forward, and still decodes bit-identically.
#[test]
fn warm_prefix_hit_skips_prefill_and_stays_bit_identical() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(31);
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    let p = prompt(4);
    let pool = pool_for(&sim, 64);

    // session A warms the prefix cache
    let a = run_pooled(&sim, &cfg, &p, 64, None, &params, &pool);
    assert_eq!(pool.stats().prefill_skips, 0);

    // dense reference for the same request (sim outputs are pure
    // functions of call inputs, so one backend serves all runs)
    let before_dense = sim.prefill_calls();
    let dense = run_dense(&sim, &cfg, &p, 64, None, &params);
    let dense_prefills = sim.prefill_calls() - before_dense;

    // warm pooled session: one fewer backend prefill, identical result
    let before_pooled = sim.prefill_calls();
    let b = run_pooled(&sim, &cfg, &p, 64, None, &params, &pool);
    let pooled_prefills = sim.prefill_calls() - before_pooled;

    assert_eq!(b.tokens, dense.tokens);
    assert_eq!(b.tokens, a.tokens, "same request must decode the same");
    assert_eq!(b.forwards, dense.forwards,
               "prefill is outside TPF accounting");
    assert_eq!(pool.stats().prefill_skips, 1);
    assert_eq!(pooled_prefills + 1, dense_prefills,
               "exactly the prompt prefill forward is saved");
}

/// Two same-prefix sessions interleaving in one scheduler share prompt
/// pages copy-on-write: different strategies diverge freely with no
/// cross-talk, each matching its own dense reference.
#[test]
fn cow_isolation_under_interleaving() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(47);
    let p = prompt(7);
    let mk = |s: Strategy| {
        let mut c = DecodeCfg::preset(s);
        c.early_stop = false;
        c
    };

    // dense references, one per strategy, same prompt
    let dense_a = run_dense(&sim, &mk(Strategy::D3llm), &p, 64, None,
                            &params);
    let dense_b = run_dense(&sim, &mk(Strategy::FastDllm), &p, 64, None,
                            &params);

    let kv = pool_for(&sim, 64);
    let mut sched: SessionPool<usize> =
        SessionPool::new().with_kv_pool(kv.clone());
    let a = DecodeSession::with_pool(&sim, mk(Strategy::D3llm), &p, 64,
                                     None, &kv)
        .unwrap();
    sched.admit("a".into(), 0, a);
    // step once so A's prefill installs + registers the prompt pages,
    // then admit the same-prompt B mid-flight (continuous serving)
    let fin = sched.step_round(&sim, &params);
    assert!(fin.is_empty());
    let b = DecodeSession::with_pool(&sim, mk(Strategy::FastDllm), &p, 64,
                                     None, &kv)
        .unwrap();
    sched.admit("b".into(), 1, b);

    let mut done: Vec<Option<GenResult>> = vec![None, None];
    while !sched.is_empty() {
        for f in sched.step_round(&sim, &params) {
            done[f.tag] = Some(f.result.expect("decode"));
        }
    }
    let got_a = done[0].take().unwrap();
    let got_b = done[1].take().unwrap();
    assert_eq!(got_a.tokens, dense_a.tokens, "A diverged under sharing");
    assert_eq!(got_b.tokens, dense_b.tokens, "B diverged under sharing");
    assert_eq!(got_a.forwards, dense_a.forwards);
    assert_eq!(got_b.forwards, dense_b.forwards);

    let s = kv.stats();
    assert_eq!(s.prefill_skips, 1, "B's prompt prefill was skipped");
    assert!(s.cow_copies >= 1,
            "a shared prompt page must be copied on first divergent write");
}

/// Budget exhaustion: admission fails cleanly once the pool cannot cover
/// a session's reservation, retirement frees the budget again, and a
/// session that could never fit is told so.
#[test]
fn budget_exhaustion_blocks_and_release_unblocks() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(3);
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    // prompt 14 + gen 64 = 78 rows -> 3 pages of 32, plus one CoW margin
    // for the partial prompt page; budget fits two sessions, not three
    let kv = pool_for(&sim, 8);
    let p = prompt(0);
    let s1 = DecodeSession::with_pool(&sim, cfg.clone(), &p, 64, None, &kv)
        .unwrap();
    let s2 = DecodeSession::with_pool(&sim, cfg.clone(), &prompt(1), 64,
                                      None, &kv)
        .unwrap();
    let err = DecodeSession::with_pool(&sim, cfg.clone(), &prompt(2), 64,
                                       None, &kv)
        .unwrap_err();
    assert!(is_pool_exhausted(&err), "{err:#}");
    assert!(kv.stats().admit_rejects >= 1);

    // retire one session -> its reservation and pages come back
    drop(s1);
    let s3 = DecodeSession::with_pool(&sim, cfg.clone(), &prompt(2), 64,
                                      None, &kv);
    assert!(s3.is_ok(), "release must unblock admission");

    // a request larger than the whole budget can never be admitted
    let too_big =
        DecodeSession::with_pool(&sim, cfg.clone(), &p, 128, None, &kv);
    assert!(too_big.is_err());

    drop(s2);
    drop(s3);
    let mut s4 = DecodeSession::with_pool(&sim, cfg, &p, 64, None, &kv)
        .unwrap();
    while !s4.step(&sim, &params).unwrap() {}
    let u = kv.usage();
    assert!(u.in_use >= 1, "live session holds pages");
}

/// Retired sessions leave their prefix pages reclaimable: later
/// same-prompt sessions still hit, and the allocator evicts them (LRU)
/// under pressure instead of failing.
#[test]
fn reclaimable_pages_serve_hits_then_evict_under_pressure() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(13);
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    // exactly one session's worth of pages (3-page span + CoW margin)
    let kv = pool_for(&sim, 4);
    let p = prompt(9);

    let a = run_pooled(&sim, &cfg, &p, 64, None, &params, &kv);
    // A retired; its prompt page stays reclaimable in the prefix index
    assert!(kv.usage().reclaimable >= 1);
    // the operator eviction hook bounds what it can evict
    assert_eq!(kv.evict_reclaimable(0), 0);

    // warm hit against a fully retired session's pages
    let b = run_pooled(&sim, &cfg, &p, 64, None, &params, &kv);
    assert_eq!(b.tokens, a.tokens);
    assert_eq!(kv.stats().prefill_skips, 1);

    // pressure: a different-prompt session drawing its full reservation
    // exhausts the slab and must evict the reclaimable prefix page
    let mut c = DecodeSession::with_pool(&sim, cfg.clone(), &prompt(20), 64,
                                         None, &kv)
        .unwrap();
    while !c.step(&sim, &params).unwrap() {}
    assert!(kv.stats().evictions >= 1,
            "allocation under pressure must evict reclaimable pages");
    drop(c);
    // the evicted prefix is gone: the next same-as-A session misses
    let d = run_pooled(&sim, &cfg, &p, 64, None, &params, &kv);
    assert_eq!(d.tokens, a.tokens);
    assert_eq!(kv.stats().prefill_skips, 1, "no further skips after evict");
}

/// The peek/admit race: the coordinator's admission probe
/// (`required_pages_for` / `can_admit`) may credit a live prefix chain
/// that retires *and* is evicted before `PagedKv::admit` lands. The
/// admit must see the post-eviction world — adopt nothing, skip nothing
/// — and the decode must stay bit-identical to the dense baseline; the
/// probe must degrade to the no-sharing worst case so the next cycle
/// re-plans honestly.
#[test]
fn eviction_between_probe_and_admit_degrades_to_fresh_pages() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(17);
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    let p = prompt(6);
    let dense = run_dense(&sim, &cfg, &p, 64, None, &params);

    let kv = pool_for(&sim, 64);
    // live session A registers the chain and survives through the probe
    let mut a = DecodeSession::with_pool(&sim, cfg.clone(), &p, 64, None,
                                         &kv)
        .unwrap();
    let done = a.step(&sim, &params).unwrap(); // prefill + registration
    assert!(!done);
    let span = (p.len() + 64).min(sim.constants().s_max);
    let warm =
        kv.required_pages_for(&p, "prefill_xla", p.len(), span, false);
    assert!(kv.can_admit(&p, "prefill_xla", p.len(), span, false));

    // the chain retires AND is recycled before the admit lands
    drop(a);
    assert!(kv.evict_reclaimable(usize::MAX) >= 1);
    let cold =
        kv.required_pages_for(&p, "prefill_xla", p.len(), span, false);
    assert!(cold > warm,
            "eviction must raise the requirement ({warm} -> {cold})");

    // the admit sees the post-eviction world: nothing adopted, no
    // prefill skip, bit-identical decode on fresh pages
    let b = run_pooled(&sim, &cfg, &p, 64, None, &params, &kv);
    assert_eq!(kv.stats().prefill_skips, 0, "stale chain must not skip");
    assert_eq!(b.tokens, dense.tokens);
    assert_eq!(b.forwards, dense.forwards);
}

/// `run_interleaved_pooled` (the coordinator-style pooled entry point)
/// serves a mixed-strategy request batch identically to the dense
/// `run_interleaved`, with prefix sharing live across the batch.
#[test]
fn run_interleaved_pooled_matches_dense() {
    let params = vec![0.5f32; 8];
    let draft = vec![0.25f32; 8];
    let sim = SimBackend::new(61);
    let cfg = {
        let mut c = DecodeCfg::preset(Strategy::D3llm);
        c.early_stop = false;
        c
    };
    let mk_reqs = || -> Vec<InterleavedRequest> {
        let mut ar = DecodeCfg::preset(Strategy::Ar);
        ar.early_stop = false;
        vec![
            InterleavedRequest { id: "p0".into(), prompt: prompt(3),
                                 gen_len: 64, cfg: None },
            InterleavedRequest { id: "p1".into(), prompt: prompt(3),
                                 gen_len: 32, cfg: None },
            InterleavedRequest { id: "p2".into(), prompt: prompt(8),
                                 gen_len: 32, cfg: Some(ar) },
        ]
    };
    let dense = run_interleaved(&sim, &cfg, &params, Some(&draft), mk_reqs())
        .unwrap();
    let kv = pool_for(&sim, 64);
    let pooled = run_interleaved_pooled(&sim, &cfg, &params, Some(&draft),
                                        mk_reqs(), &kv)
        .unwrap();
    assert_eq!(dense.len(), pooled.len());
    for ((di, dr), (pi, pr)) in dense.iter().zip(&pooled) {
        assert_eq!(di, pi);
        assert_eq!(dr.tokens, pr.tokens, "{di}");
        assert_eq!(dr.forwards, pr.forwards, "{di}");
    }
    // all sessions were admitted together (cold pool), so no prefill was
    // skipped, but pages are fully released afterwards
    let u = kv.usage();
    assert_eq!(u.in_use + u.reserved, 0);
}

/// The d3llm KV-refresh rewrites only stale pages over the paged view;
/// the prompt and long-completed blocks are skipped.
#[test]
fn kv_refresh_is_incremental_over_the_pool() {
    let params = vec![0.5f32; 8];
    let sim = SimBackend::new(11);
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    assert!(cfg.refresh_every > 0, "d3llm preset refreshes periodically");
    let kv = pool_for(&sim, 64);
    let _ = run_pooled(&sim, &cfg, &prompt(5), 96, None, &params, &kv);
    let s = kv.stats();
    assert!(s.pages_refreshed > 0, "refresh rounds must install pages");
    assert!(s.refresh_skips > 0,
            "incremental refresh must skip current pages \
             (refreshed {}, skipped {})",
            s.pages_refreshed, s.refresh_skips);
}
