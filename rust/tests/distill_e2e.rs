//! End-to-end paper pipeline on the deterministic `SimBackend` — no
//! artifacts, CI-safe: train a teacher, extract pseudo-trajectories
//! through the pooled scheduler path, distill a student with
//! `Recipe::PseudoTraj`, and evaluate an AUP sweep. Pins that the whole
//! train -> extract -> distill -> eval chain is backend-agnostic and
//! bit-deterministic.

use std::path::PathBuf;

use d3llm::data::{eval_set, main_mixture, Family};
use d3llm::decode::{Backend, DecodeCfg, SimBackend, Strategy};
use d3llm::eval::evaluate;
use d3llm::metrics::aup::{aup_from_points, Point};
use d3llm::model::ParamStore;
use d3llm::tokenizer::Tokenizer;
use d3llm::train::{train, TrainCfg};
use d3llm::trajectory::{Curriculum, Recipe};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("d3llm_e2e_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sim_cfg(name: &str, recipe: Recipe, steps: usize) -> TrainCfg {
    TrainCfg {
        name: name.into(),
        model: "main".into(),
        recipe,
        curriculum: Curriculum::paper_default(),
        steps,
        lr: 2.5e-3,
        ent_weight: 0.0,
        corpus_size: 24,
        mixture: main_mixture(),
        seed: 77,
        init_from: None,
        teacher: None,
        log_every: 0,
    }
}

#[test]
fn sim_pipeline_teacher_extract_distill_evaluate() {
    let sim = SimBackend::new(33);
    let dir = tmp_dir("pipeline");

    // ---- teacher: masked-diffusion pretraining on the sim backend
    let teacher_cfg = sim_cfg("sim-teacher", Recipe::DiffusionPretrain, 12);
    let teacher = train(&sim, &teacher_cfg, &dir).unwrap();
    let (t_first, t_last) = (teacher.log.first().unwrap().loss,
                             teacher.log.last().unwrap().loss);
    assert!(t_last < t_first, "teacher loss {t_first} -> {t_last}");
    assert!(TrainCfg::ckpt_path(&dir, "sim-teacher").exists());

    // ---- student: pseudo-trajectory distillation (extraction runs as
    // pooled sessions through the scheduler; cached next to checkpoints)
    let mut student_cfg = sim_cfg("sim-student", Recipe::PseudoTraj, 8);
    student_cfg.init_from = Some("sim-teacher".into());
    student_cfg.teacher = Some("sim-teacher".into());
    let student = train(&sim, &student_cfg, &dir).unwrap();
    // the student starts from a converged teacher, so a loss *decrease*
    // is batch-dependent (the curriculum raises the mask fraction over
    // the run); finiteness + bit-determinism are the invariants
    assert!(student.log.iter().all(|l| l.loss.is_finite()));
    assert!(dir.join("traj-cache").exists(),
            "extraction must cache next to the checkpoints");

    // ---- determinism: retraining the student reproduces the exact
    // parameter vector (the second extraction hits the disk cache)
    let mut again_cfg = student_cfg.clone();
    again_cfg.name = "sim-student-again".into();
    let again = train(&sim, &again_cfg, &dir).unwrap();
    assert_eq!(student.params.data, again.params.data,
               "distillation must be bit-deterministic");

    // checkpoint round-trip under the sim geometry
    let loaded =
        ParamStore::load(TrainCfg::ckpt_path(&dir, "sim-student")).unwrap();
    assert_eq!(loaded.data, student.params.data);
    loaded.check(sim.model_spec("main").unwrap()).unwrap();

    // ---- evaluate: AUP threshold sweep over the distilled student,
    // decodes routed through the interleaved scheduler
    let c = sim.constants().clone();
    let tk = Tokenizer::new(c.vocab).unwrap();
    let samples = eval_set(&tk, Family::Gsm8k, 6, 42);
    let mut points = Vec::new();
    for th in [0.25f32, 0.45, 0.8] {
        let cfg = DecodeCfg::preset(Strategy::D3llm).with_threshold(th);
        let out = evaluate(&sim, &cfg, &student.params.data, None, &tk,
                           &samples, false)
            .unwrap();
        assert_eq!(out.metrics.samples, samples.len());
        assert!(out.metrics.tpf() >= 1.0,
                "parallel decoding must average >= 1 token/forward");
        points.push(Point { rho: out.metrics.tpf(),
                            acc: out.metrics.accuracy() });
    }
    let aup = aup_from_points(&points, 3.0, None);
    assert!(aup.is_finite() && aup >= 0.0);

    // eval determinism: the same sweep point reproduces exactly
    let cfg = DecodeCfg::preset(Strategy::D3llm).with_threshold(0.45);
    let a = evaluate(&sim, &cfg, &student.params.data, None, &tk, &samples,
                     false)
        .unwrap();
    let b = evaluate(&sim, &cfg, &student.params.data, None, &tk, &samples,
                     false)
        .unwrap();
    assert_eq!(a.metrics.forwards, b.metrics.forwards);
    assert_eq!(a.metrics.gen_tokens, b.metrics.gen_tokens);
    assert_eq!(a.metrics.correct, b.metrics.correct);
}

#[test]
fn pooled_eval_matches_sequential_eval() {
    use d3llm::eval::evaluate_pooled;

    let sim = SimBackend::new(44);
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let params = ParamStore::init(&spec, 11).data;
    let tk = Tokenizer::new(c.vocab).unwrap();
    let samples = eval_set(&tk, Family::Math, 5, 7);
    let cfg = DecodeCfg::preset(Strategy::D3llm);

    let seq = evaluate_pooled(&sim, &cfg, &params, None, &tk, &samples,
                              false, 1)
        .unwrap();
    let pooled = evaluate_pooled(&sim, &cfg, &params, None, &tk, &samples,
                                 false, 4)
        .unwrap();
    assert_eq!(seq.metrics.correct, pooled.metrics.correct);
    assert_eq!(seq.metrics.forwards, pooled.metrics.forwards);
    assert_eq!(seq.metrics.gen_tokens, pooled.metrics.gen_tokens);
    assert_eq!(seq.mix.window_forwards, pooled.mix.window_forwards);
    // the width-4 run must have coalesced same-shape rounds
    assert!(sim.max_window_batch() >= 2,
            "pooled eval should batch same-shape rounds");
}
