//! Integration: every decode strategy runs end to end against the real
//! executables and obeys its defining invariants. Uses random weights
//! (strategy mechanics must hold for any model). Skips without artifacts.

use d3llm::decode::{self, DecodeCfg, SelMetric, Strategy};
use d3llm::model::ParamStore;
use d3llm::runtime::Engine;
use d3llm::tokenizer::{EOS, MASK};

fn setup() -> Option<(Engine, Vec<f32>, Vec<f32>, Vec<i32>)> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing");
        return None;
    }
    let eng = Engine::load("artifacts").unwrap();
    let main = ParamStore::init(eng.manifest.model("main").unwrap(), 3).data;
    let draft =
        ParamStore::init(eng.manifest.model("draft").unwrap(), 4).data;
    let prompt: Vec<i32> = (0..24).map(|i| 5 + i % 90).collect();
    Some((eng, main, draft, prompt))
}

#[test]
fn vanilla_is_one_token_per_forward() {
    let Some((eng, params, _, prompt)) = setup() else { return };
    let cfg = DecodeCfg::preset(Strategy::Vanilla);
    let r = decode::generate(&eng, &cfg, &params, None, &prompt, 64).unwrap();
    // no early stop, no cache: forwards == gen capacity, TPF == 1
    assert_eq!(r.forwards, 64);
    assert_eq!(r.mix.full_forwards, 64);
    assert_eq!(r.mix.window_forwards, 0);
    assert!(r.tokens.len() <= 64);
    assert!(!r.tokens.contains(&MASK));
}

#[test]
fn ar_is_exactly_one_token_per_step() {
    let Some((eng, params, _, prompt)) = setup() else { return };
    let cfg = DecodeCfg::preset(Strategy::Ar);
    let r = decode::generate(&eng, &cfg, &params, None, &prompt, 64).unwrap();
    assert_eq!(r.forwards, r.tokens.len());
    assert!((r.tpf() - 1.0).abs() < 1e-9);
    assert_eq!(r.mix.ar_steps, r.forwards);
}

#[test]
fn fast_dllm_decodes_all_blocks_with_cache() {
    let Some((eng, params, _, prompt)) = setup() else { return };
    let mut cfg = DecodeCfg::preset(Strategy::FastDllm);
    cfg.early_stop = false;
    let r = decode::generate(&eng, &cfg, &params, None, &prompt, 96).unwrap();
    // every position is decoded; output() may truncate at a (random) EOS
    assert!(!r.tokens.is_empty() && r.tokens.len() <= 96);
    assert!(!r.tokens.contains(&MASK));
    assert!(r.mix.window_forwards > 0, "cache path must be used");
    assert!(r.forwards <= 96, "parallel decode can't exceed 1/step");
    // low threshold => high parallelism
    let mut loose = cfg.clone();
    loose.metric = SelMetric::Conf(0.0);
    let r2 =
        decode::generate(&eng, &loose, &params, None, &prompt, 96).unwrap();
    assert!(r2.forwards < r.forwards || r.forwards <= 6,
            "threshold 0 should decode blocks in very few forwards");
}

#[test]
fn d3llm_multi_block_produces_complete_output_and_refreshes() {
    let Some((eng, params, _, prompt)) = setup() else { return };
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false; // random weights: EOS may appear anywhere
    let r = decode::generate(&eng, &cfg, &params, None, &prompt, 128)
        .unwrap();
    // full region decoded (output may truncate at a random EOS)
    assert!(!r.tokens.is_empty() && r.tokens.len() <= 128);
    assert!(!r.tokens.contains(&MASK));
    assert!(r.rounds >= 4, "multi-block decode must take several rounds");
    // stabilizing + periodic refresh mean full forwards were used
    assert!(r.mix.full_forwards > 0, "KV refresh must run");
    assert!(r.mix.window_forwards > 0);
}

#[test]
fn d2f_never_refreshes() {
    let Some((eng, params, _, prompt)) = setup() else { return };
    let mut cfg = DecodeCfg::preset(Strategy::D2f);
    cfg.early_stop = false;
    let r = decode::generate(&eng, &cfg, &params, None, &prompt, 96).unwrap();
    assert!(!r.tokens.is_empty() && r.tokens.len() <= 96);
    assert!(!r.tokens.contains(&MASK));
    assert_eq!(r.mix.full_forwards, 0, "D2F has no refresh/stabilize");
}

#[test]
fn threshold_sweep_moves_tpf_monotonically_for_conf_methods() {
    let Some((eng, params, _, prompt)) = setup() else { return };
    let mut last_forwards = 0usize;
    for (i, th) in [0.99f32, 0.5, 0.0].iter().enumerate() {
        let mut cfg = DecodeCfg::preset(Strategy::FastDllm);
        cfg.early_stop = false;
        cfg.metric = SelMetric::Conf(*th);
        let r = decode::generate(&eng, &cfg, &params, None, &prompt, 96)
            .unwrap();
        if i > 0 {
            assert!(r.forwards <= last_forwards,
                    "lower threshold must not slow decoding");
        }
        last_forwards = r.forwards;
    }
}

#[test]
fn spec_decoding_equals_target_greedy() {
    let Some((eng, params, draft, prompt)) = setup() else { return };
    // lossless property: spec output == plain AR greedy output
    let ar = decode::generate(&eng, &DecodeCfg::preset(Strategy::Ar),
                              &params, None, &prompt, 64)
        .unwrap();
    let spec = decode::generate(&eng, &DecodeCfg::preset(Strategy::Spec),
                                &params, Some(&draft), &prompt, 64)
        .unwrap();
    let n = ar.tokens.len().min(spec.tokens.len());
    assert_eq!(&spec.tokens[..n], &ar.tokens[..n],
               "speculative decode must be lossless");
    // ... and strictly fewer target forwards than tokens (gamma > 0)
    assert!(spec.forwards <= spec.tokens.len());
    assert!(spec.draft_forwards > 0);
}

#[test]
fn early_stop_cuts_forwards_when_eos_is_early() {
    let Some((eng, _, _, _)) = setup() else { return };
    // train nothing: instead force EOS early by biasing the embedding row
    // of EOS to match the average hidden state — cheap trick: use params
    // where the EOS embedding is huge, making EOS the argmax everywhere.
    let spec = eng.manifest.model("main").unwrap().clone();
    let mut p = ParamStore::init(&spec, 5);
    let d = spec.d_model;
    // embed row for EOS = large constant vector
    for j in 0..d {
        p.data[(EOS as usize) * d + j] = 2.0;
    }
    let prompt: Vec<i32> = (0..16).map(|i| 5 + i % 60).collect();
    let mut with = DecodeCfg::preset(Strategy::D3llm);
    with.early_stop = true;
    let mut without = with.clone();
    without.early_stop = false;
    let r_with =
        decode::generate(&eng, &with, &p.data, None, &prompt, 128).unwrap();
    let r_without =
        decode::generate(&eng, &without, &p.data, None, &prompt, 128)
            .unwrap();
    assert!(r_with.forwards <= r_without.forwards);
    assert!(r_with.tokens.contains(&EOS));
}
