//! Integration: AOT artifacts load, compile and execute through PJRT, and
//! the serving-graph semantics hold end to end (pallas == xla variants,
//! decode-vs-prefill consistency, AR cache exactness).
//!
//! Requires `make artifacts` (skips politely otherwise).

use d3llm::model::{exec, KvCache, ParamStore};
use d3llm::runtime::Engine;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load("artifacts").expect("engine"))
}

#[test]
fn manifest_and_prefill_roundtrip() {
    let Some(eng) = engine() else { return };
    let c = eng.manifest.constants.clone();
    assert_eq!(c.block, 32);
    let spec = eng.manifest.model("main").unwrap().clone();
    let params = ParamStore::init(&spec, 7);

    let s = c.s_max;
    let mut tokens = vec![c.mask_id; s];
    for (i, t) in tokens.iter_mut().enumerate().take(64) {
        *t = 5 + (i as i32 % 100);
    }
    let valid: Vec<f32> =
        (0..s).map(|i| if i < 128 { 1.0 } else { 0.0 }).collect();

    let px = exec::prefill(&eng, "prefill_xla", &params.data, &tokens, &valid)
        .expect("prefill_xla");
    assert_eq!(px.argmax.len(), s);
    assert_eq!(px.kcache.len(), spec.n_layers * s * spec.d_kv);
    // stats are sane on valid positions
    for i in 0..128 {
        assert!(px.conf[i] > 0.0 && px.conf[i] <= 1.0 + 1e-5, "conf[{i}]");
        assert!(
            px.entropy[i] >= -1e-4
                && px.entropy[i] <= (spec.vocab as f32).ln() + 1e-3,
            "entropy[{i}]={}",
            px.entropy[i]
        );
        assert!((0..spec.vocab as i32).contains(&px.argmax[i]));
    }

    // the Pallas hot path must agree with the fused-XLA path
    let pp =
        exec::prefill(&eng, "prefill_pallas", &params.data, &tokens, &valid)
            .expect("prefill_pallas");
    for i in 0..128 {
        assert_eq!(pp.argmax[i], px.argmax[i], "argmax[{i}]");
        assert!((pp.conf[i] - px.conf[i]).abs() < 1e-4, "conf[{i}]");
        assert!((pp.entropy[i] - px.entropy[i]).abs() < 1e-3, "ent[{i}]");
    }
}

#[test]
fn decode_against_empty_cache_matches_prefill() {
    let Some(eng) = engine() else { return };
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main").unwrap().clone();
    let params = ParamStore::init(&spec, 9);
    let w = c.window;

    // a window of real tokens at positions 0..w with nothing cached
    let win_tokens: Vec<i32> = (0..w).map(|i| 5 + (i as i32 % 90)).collect();
    let win_pos: Vec<i32> = (0..w as i32).collect();
    let win_valid = vec![1.0f32; w];
    let cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);

    let d = exec::decode_window(&eng, "decode_xla", &params.data, &win_tokens,
                                &win_pos, &win_valid, &cache)
        .expect("decode");

    // reference: prefill over the same tokens, valid only on 0..w
    let mut tokens = vec![0i32; c.s_max];
    tokens[..w].copy_from_slice(&win_tokens);
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < w { 1.0 } else { 0.0 }).collect();
    let p = exec::prefill(&eng, "prefill_xla", &params.data, &tokens, &valid)
        .expect("prefill");

    for i in 0..w {
        assert_eq!(d.argmax[i], p.argmax[i], "argmax[{i}]");
        assert!((d.conf[i] - p.conf[i]).abs() < 1e-4);
    }
    // window KV rows must equal the prefill cache rows at those positions
    for l in 0..spec.n_layers {
        for i in 0..w {
            let a = (l * w + i) * spec.d_kv;
            let b = (l * c.s_max + i) * spec.d_kv;
            for j in 0..spec.d_kv {
                assert!(
                    (d.k_win[a + j] - p.kcache[b + j]).abs() < 1e-4,
                    "k mismatch l={l} i={i} j={j}"
                );
            }
        }
    }
}

#[test]
fn ar_cache_is_exact() {
    let Some(eng) = engine() else { return };
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main").unwrap().clone();
    let params = ParamStore::init(&spec, 11);
    let (n_prompt, w) = (50usize, c.verify_w);

    let seq: Vec<i32> = (0..(n_prompt + w) as i32).map(|i| 5 + i % 97).collect();
    let mut full = vec![0i32; c.s_max];
    full[..seq.len()].copy_from_slice(&seq);
    let valid_full: Vec<f32> = (0..c.s_max)
        .map(|i| if i < n_prompt + w { 1.0 } else { 0.0 })
        .collect();
    let reference =
        exec::prefill(&eng, "ar_prefill", &params.data, &full, &valid_full)
            .expect("ar_prefill full");

    // cached prefix + windowed verify
    let mut prompt = vec![0i32; c.s_max];
    prompt[..n_prompt].copy_from_slice(&seq[..n_prompt]);
    let valid_p: Vec<f32> = (0..c.s_max)
        .map(|i| if i < n_prompt { 1.0 } else { 0.0 })
        .collect();
    let pre = exec::prefill(&eng, "ar_prefill", &params.data, &prompt, &valid_p)
        .expect("ar_prefill prompt");
    let mut cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);
    cache.install_full(&pre.kcache, &pre.vcache, 0, n_prompt);

    let win_pos: Vec<i32> =
        (n_prompt as i32..(n_prompt + w) as i32).collect();
    let out = exec::decode_window(&eng, "ar_verify", &params.data,
                                  &seq[n_prompt..], &win_pos,
                                  &vec![1.0; w], &cache)
        .expect("ar_verify");

    for i in 0..w {
        assert_eq!(out.argmax[i], reference.argmax[n_prompt + i],
                   "argmax[{i}]");
        assert!((out.conf[i] - reference.conf[n_prompt + i]).abs() < 1e-4);
    }
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(eng) = engine() else { return };
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main").unwrap().clone();
    let params = ParamStore::init(&spec, 13);
    let (b, s) = (c.b_train, c.s_train);

    // memorise a fixed masked batch
    let mut rng = d3llm::util::rng::Rng::new(5);
    let mut tokens = vec![0i32; b * s];
    let mut labels = vec![0i32; b * s];
    let mut loss_mask = vec![0.0f32; b * s];
    let attn_valid = vec![1.0f32; b * s];
    for i in 0..b * s {
        let t = rng.range(5, c.vocab as i64) as i32;
        labels[i] = t;
        if rng.bool(0.3) {
            tokens[i] = c.mask_id;
            loss_mask[i] = 1.0;
        } else {
            tokens[i] = t;
        }
    }

    let mut p = params.data.clone();
    let mut m = vec![0.0f32; p.len()];
    let mut v = vec![0.0f32; p.len()];
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 1..=30 {
        let out = exec::train_step(&eng, "train_diff", &p, &m, &v, step,
                                   &tokens, &labels, &loss_mask, &attn_valid,
                                   2e-3, 0.0)
            .expect("train");
        if step == 1 {
            first = out.loss;
        }
        last = out.loss;
        p = out.params;
        m = out.m;
        v = out.v;
    }
    assert!(last < 0.6 * first, "loss {first} -> {last}");
}

#[test]
fn trajectory_ranks_are_block_ordered() {
    let Some(eng) = engine() else { return };
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main").unwrap().clone();
    let params = ParamStore::init(&spec, 17);
    let (b, s, g) = (c.b_traj, c.s_train, c.gen_train);
    let prompt_len = 32usize;

    let mut tokens = vec![c.mask_id; b * s];
    let mut attn_valid = vec![0.0f32; b * s];
    let mut gen_mask = vec![0.0f32; b * s];
    let mut rng = d3llm::util::rng::Rng::new(23);
    for bi in 0..b {
        for i in 0..prompt_len {
            tokens[bi * s + i] = rng.range(5, c.vocab as i64) as i32;
        }
        for i in 0..prompt_len + g {
            attn_valid[bi * s + i] = 1.0;
        }
        for i in prompt_len..prompt_len + g {
            gen_mask[bi * s + i] = 1.0;
        }
    }

    let out = exec::trajectory(&eng, &params.data, &tokens, &attn_valid,
                               &gen_mask)
        .expect("trajectory");
    for bi in 0..b {
        let ranks: Vec<i32> =
            (0..g).map(|i| out.rank[bi * s + prompt_len + i]).collect();
        let mut sorted = ranks.clone();
        sorted.sort();
        assert_eq!(sorted, (0..g as i32).collect::<Vec<_>>(), "b={bi}");
        // block-diffusion order
        let nb = g / c.block;
        for blk in 0..nb - 1 {
            let max_this =
                ranks[blk * c.block..(blk + 1) * c.block].iter().max().unwrap();
            let min_next = ranks[(blk + 1) * c.block..(blk + 2) * c.block]
                .iter()
                .min()
                .unwrap();
            assert!(max_this < min_next, "b={bi} blk={blk}");
        }
        // prompt untouched
        for i in 0..prompt_len {
            assert_eq!(out.rank[bi * s + i], c.rank_never);
        }
    }
}
