//! The `DecodePolicy` API contract, over the deterministic `SimBackend`:
//!
//!   * `Strategy` is a closed, round-trippable enum: `parse(name(s)) == s`
//!     for every variant, and every variant constructs a resumable
//!     `DecodeSession` (this replaces the deleted `is_resumable()` split —
//!     a new strategy that cannot build a session fails here);
//!   * the policy-driven Ar / Vanilla / FastDllm / Spec paths are pinned
//!     token-for-token (and forward-for-forward) against reference
//!     implementations that replicate the pre-refactor free-function
//!     decode loops exactly.

use d3llm::decode::{self, Backend, DecodeCfg, DecodeSession, GenResult,
                    SelMetric, SeqState, SimBackend, Strategy};
use d3llm::model::KvCache;
use d3llm::tokenizer::{EOS, MASK};

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(10 + k % 5)).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

// ------------------------------------------------------------ strategy api

#[test]
fn strategy_names_round_trip_exhaustively() {
    assert_eq!(Strategy::ALL.len(), 7, "keep ALL in sync with the enum");
    let mut seen = Vec::new();
    for s in Strategy::ALL {
        assert_eq!(Strategy::parse(s.name()), Some(s), "{}", s.name());
        assert!(!seen.contains(&s.name()), "duplicate name {}", s.name());
        seen.push(s.name());
    }
    assert_eq!(Strategy::parse("bogus"), None);
}

#[test]
fn every_strategy_builds_a_resumable_session() {
    let sim = SimBackend::new(1);
    let draft = vec![0.25f32; 8];
    let prompt = prompt_for(0);
    for s in Strategy::ALL {
        let cfg = DecodeCfg::preset(s);
        let session =
            DecodeSession::with_draft(&sim, cfg, &prompt, 32, Some(&draft));
        assert!(session.is_ok(), "{}: cannot build a session", s.name());
        let session = session.unwrap();
        assert!(session.is_runnable(), "{}", s.name());
        assert!(!session.is_done(), "{}", s.name());
    }
    // spec is the only strategy that needs the draft checkpoint
    for s in Strategy::ALL {
        let built = DecodeSession::new(&sim, DecodeCfg::preset(s), &prompt,
                                       32);
        assert_eq!(built.is_err(), s == Strategy::Spec, "{}", s.name());
    }
}

#[test]
fn every_strategy_decodes_to_completion_on_the_sim() {
    let sim = SimBackend::new(3);
    let params = vec![0.5f32; 8];
    let draft = vec![0.25f32; 8];
    let prompt = prompt_for(1);
    for s in Strategy::ALL {
        let mut cfg = DecodeCfg::preset(s);
        cfg.early_stop = false; // sim argmax never emits EOS by default
        let r = decode::generate(&sim, &cfg, &params, Some(&draft), &prompt,
                                 32)
            .unwrap_or_else(|e| panic!("{}: {e:#}", s.name()));
        assert_eq!(r.tokens.len(), 32, "{}: incomplete", s.name());
        assert!(!r.tokens.contains(&MASK), "{}", s.name());
        assert!(r.forwards > 0, "{}", s.name());
        assert!(r.wall_secs > 0.0, "{}: wall time not recorded", s.name());
    }
}

// ---------------------------------------------------- legacy reference: ar

/// Pre-refactor `decode_ar` (rust/src/decode/ar.rs at PR 1), ported
/// verbatim from `&Engine` to `&dyn Backend`.
fn legacy_ar(backend: &dyn Backend, params: &[f32], prompt: &[i32],
             gen_len: usize) -> GenResult {
    let c = backend.constants().clone();
    let spec = backend.model_spec("main").unwrap().clone();
    assert!(prompt.len() + gen_len <= c.s_max);

    let mut res = GenResult::default();
    let mut cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);

    let p = prompt.len();
    let mut tokens = vec![0i32; c.s_max];
    tokens[..p].copy_from_slice(prompt);
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < p { 1.0 } else { 0.0 }).collect();
    let pre = backend.prefill("ar_prefill", params, &tokens, &valid).unwrap();
    cache.install_full(&pre.kcache, &pre.vcache, 0, p - 1);

    let mut generated = Vec::with_capacity(gen_len);
    let mut cur_tok = prompt[p - 1];
    let mut cur_pos = p - 1;
    for _ in 0..gen_len {
        let out = backend
            .decode_window("ar_step", params, &[cur_tok], &[cur_pos as i32],
                           &[1.0], &cache)
            .unwrap();
        res.forwards += 1;
        res.mix.ar_steps += 1;
        cache.commit_window_rows(&out.k_win, &out.v_win, 1, &[(0, cur_pos)]);
        let next = out.argmax[0];
        generated.push(next);
        if next == EOS {
            break;
        }
        cur_pos += 1;
        cur_tok = next;
    }

    res.unmasked = generated.len();
    res.tokens = generated;
    res.mix.gen_tokens = res.unmasked;
    res
}

// ------------------------------------------------- legacy: single block

/// Pre-refactor `decode_single_block` (no-cache branch), ported verbatim.
fn legacy_nocache(backend: &dyn Backend, cfg: &DecodeCfg, params: &[f32],
                  prompt: &[i32], gen_len: usize) -> GenResult {
    let c = backend.constants().clone();
    let (prefill_exec, _) = decode::exec_names(&cfg.variant);
    let mut st = SeqState::new(prompt, gen_len, c.block, c.s_max);
    let mut res = GenResult::default();

    let valid = st.full_valid();
    while let Some(b) = st.first_incomplete_block() {
        let out = backend
            .prefill(&prefill_exec, params, &st.tokens, &valid)
            .unwrap();
        res.forwards += 1;
        res.mix.full_forwards += 1;
        res.rounds += 1;

        let (lo, hi) = st.block_range(b);
        let mut best: Option<(usize, f32)> = None;
        let mut selected = Vec::new();
        for i in lo..hi {
            if st.tokens[i] != MASK {
                continue;
            }
            let sc = cfg.metric.score(out.conf[i], out.entropy[i]);
            if best.map(|(_, s)| sc > s).unwrap_or(true) {
                best = Some((i, sc));
            }
            if cfg.metric.selects(out.conf[i], out.entropy[i]) {
                selected.push(i);
            }
        }
        if selected.is_empty() {
            selected.push(best.expect("incomplete block has masks").0);
        }
        for i in selected {
            st.tokens[i] = out.argmax[i];
        }
        if cfg.early_stop && st.eos_settled() {
            break;
        }
    }

    res.tokens = st.output();
    res.unmasked = st.unmasked_count();
    res.mix.gen_tokens = res.unmasked;
    res
}

/// Pre-refactor `decode_single_block` (cached branch), ported verbatim.
fn legacy_cached(backend: &dyn Backend, cfg: &DecodeCfg, params: &[f32],
                 prompt: &[i32], gen_len: usize) -> GenResult {
    let c = backend.constants().clone();
    let spec = backend.model_spec("main").unwrap().clone();
    let (prefill_exec, decode_exec) = decode::exec_names(&cfg.variant);
    let window = c.window;
    let mut st = SeqState::new(prompt, gen_len, c.block, c.s_max);
    let mut res = GenResult::default();

    let mut cache = KvCache::new(spec.n_layers, st.s_max, spec.d_kv);
    let mut pv = vec![0.0f32; st.s_max];
    for v in pv.iter_mut().take(st.prompt_len) {
        *v = 1.0;
    }
    let pre = backend
        .prefill(&prefill_exec, params, &st.tokens, &pv)
        .unwrap();
    cache.install_full(&pre.kcache, &pre.vcache, 0, st.prompt_len);

    'blocks: while let Some(b) = st.first_incomplete_block() {
        let (lo, hi) = st.block_range(b);
        loop {
            let mut win_tokens = vec![0i32; window];
            let mut win_pos = vec![0i32; window];
            let mut win_valid = vec![0.0f32; window];
            for (off, p) in (lo..hi).enumerate() {
                win_tokens[off] = st.tokens[p];
                win_pos[off] = p as i32;
                win_valid[off] = 1.0;
            }
            let out = backend
                .decode_window(&decode_exec, params, &win_tokens, &win_pos,
                               &win_valid, &cache)
                .unwrap();
            res.forwards += 1;
            res.mix.window_forwards += 1;
            res.rounds += 1;

            let mut best: Option<(usize, f32)> = None;
            let mut selected = Vec::new();
            for off in 0..(hi - lo) {
                let p = lo + off;
                if st.tokens[p] != MASK {
                    continue;
                }
                let sc = cfg.metric.score(out.conf[off], out.entropy[off]);
                if best.map(|(_, s)| sc > s).unwrap_or(true) {
                    best = Some((off, sc));
                }
                if cfg.metric.selects(out.conf[off], out.entropy[off]) {
                    selected.push(off);
                }
            }
            if selected.is_empty() {
                selected.push(best.expect("block has masks").0);
            }
            for off in selected {
                st.tokens[lo + off] = out.argmax[off];
            }

            if st.block_complete(b) {
                let pairs: Vec<(usize, usize)> =
                    (0..(hi - lo)).map(|off| (off, lo + off)).collect();
                cache.commit_window_rows(&out.k_win, &out.v_win, window,
                                         &pairs);
                if cfg.early_stop && st.eos_settled() {
                    break 'blocks;
                }
                break;
            }
            if cfg.early_stop && st.eos_settled() {
                break 'blocks;
            }
        }
    }

    res.tokens = st.output();
    res.unmasked = st.unmasked_count();
    res.mix.gen_tokens = res.unmasked;
    res
}

// ------------------------------------------------------- legacy: spec

/// Pre-refactor `decode_spec`, ported verbatim.
fn legacy_spec(backend: &dyn Backend, params: &[f32], draft_params: &[f32],
               prompt: &[i32], gen_len: usize, gamma: usize) -> GenResult {
    let c = backend.constants().clone();
    let spec_t = backend.model_spec("main").unwrap().clone();
    let spec_d = backend.model_spec("draft").unwrap().clone();
    let w = c.verify_w;
    let gamma = gamma.min(w - 1).max(1);
    let p = prompt.len();
    assert!(p + gen_len <= c.s_max);

    let mut res = GenResult::default();
    let mut t_cache = KvCache::new(spec_t.n_layers, c.s_max, spec_t.d_kv);
    let mut d_cache = KvCache::new(spec_d.n_layers, c.s_max, spec_d.d_kv);

    let mut tokens = vec![0i32; c.s_max];
    tokens[..p].copy_from_slice(prompt);
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < p { 1.0 } else { 0.0 }).collect();
    let pre_t =
        backend.prefill("ar_prefill", params, &tokens, &valid).unwrap();
    t_cache.install_full(&pre_t.kcache, &pre_t.vcache, 0, p - 1);
    let pre_d = backend
        .prefill("draft_ar_prefill", draft_params, &tokens, &valid)
        .unwrap();
    d_cache.install_full(&pre_d.kcache, &pre_d.vcache, 0, p - 1);

    let mut pending = prompt[p - 1];
    let mut pending_pos = p - 1;
    let mut generated: Vec<i32> = Vec::with_capacity(gen_len);

    'outer: while generated.len() < gen_len {
        let mut proposals = Vec::with_capacity(gamma);
        let mut d_tok = pending;
        let mut d_pos = pending_pos;
        for _ in 0..gamma {
            let out = backend
                .decode_window("draft_ar_step", draft_params, &[d_tok],
                               &[d_pos as i32], &[1.0], &d_cache)
                .unwrap();
            res.draft_forwards += 1;
            d_cache.commit_window_rows(&out.k_win, &out.v_win, 1,
                                       &[(0, d_pos)]);
            let t = out.argmax[0];
            proposals.push(t);
            d_pos += 1;
            d_tok = t;
        }

        let mut win_tokens = vec![0i32; w];
        let mut win_pos = vec![0i32; w];
        let mut win_valid = vec![0.0f32; w];
        win_tokens[0] = pending;
        win_pos[0] = pending_pos as i32;
        win_valid[0] = 1.0;
        for (j, &d) in proposals.iter().enumerate() {
            win_tokens[j + 1] = d;
            win_pos[j + 1] = (pending_pos + 1 + j) as i32;
            win_valid[j + 1] = 1.0;
        }
        let out = backend
            .decode_window("ar_verify", params, &win_tokens, &win_pos,
                           &win_valid, &t_cache)
            .unwrap();
        res.forwards += 1;
        res.mix.window_forwards += 1;
        res.rounds += 1;

        let mut accepted = 0usize;
        while accepted < gamma && out.argmax[accepted] == proposals[accepted]
        {
            accepted += 1;
        }
        let commit: Vec<(usize, usize)> =
            (0..=accepted).map(|j| (j, pending_pos + j)).collect();
        t_cache.commit_window_rows(&out.k_win, &out.v_win, w, &commit);

        for &d in proposals.iter().take(accepted) {
            generated.push(d);
            if d == EOS || generated.len() >= gen_len {
                break 'outer;
            }
        }
        let bonus = out.argmax[accepted];
        generated.push(bonus);
        if bonus == EOS {
            break;
        }

        d_cache.invalidate_from(pending_pos + accepted + 1);
        pending = bonus;
        pending_pos += accepted + 1;
    }

    res.unmasked = generated.len();
    res.tokens = generated;
    res.mix.gen_tokens = res.unmasked;
    res
}

// ------------------------------------------------------------ equivalence

fn assert_same(id: &str, new: &GenResult, old: &GenResult) {
    assert_eq!(new.tokens, old.tokens, "{id}: tokens diverged");
    assert_eq!(new.unmasked, old.unmasked, "{id}: unmasked diverged");
    assert_eq!(new.forwards, old.forwards, "{id}: forwards diverged");
    assert_eq!(new.draft_forwards, old.draft_forwards, "{id}");
    assert_eq!(new.mix.ar_steps, old.mix.ar_steps, "{id}");
    assert_eq!(new.mix.full_forwards, old.mix.full_forwards, "{id}");
    assert_eq!(new.mix.window_forwards, old.mix.window_forwards, "{id}");
}

#[test]
fn policy_ar_matches_legacy_free_function() {
    for seed in [1u64, 7, 42] {
        let sim = SimBackend::new(seed);
        let params = vec![0.5f32; 8];
        let prompt = prompt_for(seed as usize);
        let old = legacy_ar(&sim, &params, &prompt, 40);
        let new = decode::generate(&sim, &DecodeCfg::preset(Strategy::Ar),
                                   &params, None, &prompt, 40)
            .unwrap();
        assert_same(&format!("ar/{seed}"), &new, &old);
    }
}

#[test]
fn policy_vanilla_matches_legacy_free_function() {
    for seed in [2u64, 9] {
        let sim = SimBackend::new(seed);
        let params = vec![0.5f32; 8];
        let prompt = prompt_for(seed as usize);
        let cfg = DecodeCfg::preset(Strategy::Vanilla);
        let old = legacy_nocache(&sim, &cfg, &params, &prompt, 64);
        let new =
            decode::generate(&sim, &cfg, &params, None, &prompt, 64).unwrap();
        assert_same(&format!("vanilla/{seed}"), &new, &old);
        // vanilla's defining invariant: exactly one token per forward
        assert_eq!(new.forwards, 64);
    }
}

#[test]
fn policy_fast_dllm_matches_legacy_free_function() {
    for seed in [3u64, 11, 27] {
        let sim = SimBackend::new(seed);
        let params = vec![0.5f32; 8];
        let prompt = prompt_for(seed as usize);
        for threshold in [0.85f32, 0.5] {
            let mut cfg = DecodeCfg::preset(Strategy::FastDllm);
            cfg.early_stop = false;
            cfg.metric = SelMetric::Conf(threshold);
            let old = legacy_cached(&sim, &cfg, &params, &prompt, 96);
            let new = decode::generate(&sim, &cfg, &params, None, &prompt,
                                       96)
                .unwrap();
            assert_same(&format!("fast-dllm/{seed}/{threshold}"), &new,
                        &old);
        }
    }
}

#[test]
fn policy_spec_matches_legacy_free_function() {
    for seed in [4u64, 13] {
        let sim = SimBackend::new(seed);
        let params = vec![0.5f32; 8];
        let draft = vec![0.25f32; 8];
        let prompt = prompt_for(seed as usize);
        let cfg = DecodeCfg::preset(Strategy::Spec);
        let old = legacy_spec(&sim, &params, &draft, &prompt, 48, cfg.gamma);
        let new = decode::generate(&sim, &cfg, &params, Some(&draft),
                                   &prompt, 48)
            .unwrap();
        assert_same(&format!("spec/{seed}"), &new, &old);
        assert!(new.draft_forwards > 0);
    }
}
