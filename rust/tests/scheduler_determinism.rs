//! Deterministic scheduler tests over the SimBackend: no artifacts, no
//! PJRT, fully reproducible.
//!
//!   * N queued requests with mixed gen_lens all complete;
//!   * round-robin fairness bounds per-session step gaps;
//!   * `max_concurrent_sessions = 1` reproduces the classic batch=1
//!     sequential decode token-for-token (and so does any pool width,
//!     since a session's trajectory is schedule-independent);
//!   * `step_round` coalesces same-shape rounds into one B>1 batched
//!     backend call with outputs bit-identical to the B=1 path, and a
//!     pool can mix strategies (d3llm + ar + spec) freely;
//!   * under `round_width` pressure the pool schedules EDF (earliest
//!     deadline first, deadline-free after deadlined, overdue last),
//!     preempts by pausing, and a paused session resumes bit-identical;
//!   * a 4-replica fleet placed by the prefix-affinity router core is
//!     bit-identical to a 1-replica reference on a shared-prefix mix,
//!     and a mid-run replica kill drains its backlog to the survivors
//!     without losing a single queued request;
//!   * a session paused past `spill_after_rounds` releases its paged KV,
//!     re-prefills on resume, and still decodes bit-identically.

use d3llm::coordinator::scheduler::{run_interleaved, InterleavedRequest,
                                    SessionPool};
use d3llm::decode::multi_block::decode_multi_block;
use d3llm::decode::{self, DecodeCfg, DecodeSession, GenResult, SimBackend,
                    Strategy};

fn test_cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false; // sim argmax never emits EOS by default
    cfg
}

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(8 + k % 5)).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

/// The mixed workload: 8 requests spanning every gen_len the geometry
/// supports.
fn mixed_requests() -> Vec<InterleavedRequest> {
    let lens = [32usize, 128, 64, 96, 32, 128, 96, 64];
    lens.iter()
        .enumerate()
        .map(|(k, &gen_len)| InterleavedRequest {
            id: format!("r{k}"),
            prompt: prompt_for(k),
            gen_len,
            cfg: None,
        })
        .collect()
}

fn sequential_reference(sim: &SimBackend, params: &[f32])
                        -> Vec<(String, GenResult)> {
    mixed_requests()
        .into_iter()
        .map(|r| {
            let cfg = test_cfg();
            let out =
                decode_multi_block(sim, &cfg, params, &r.prompt, r.gen_len)
                    .unwrap();
            (r.id, out)
        })
        .collect()
}

#[test]
fn mixed_gen_lens_all_complete() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let results =
        run_interleaved(&sim, &test_cfg(), &params, None, mixed_requests())
            .unwrap();
    assert_eq!(results.len(), 8);
    let lens = [32usize, 128, 64, 96, 32, 128, 96, 64];
    for (k, (id, r)) in results.iter().enumerate() {
        assert_eq!(id, &format!("r{k}"), "input order preserved");
        assert_eq!(r.tokens.len(), lens[k], "{id} incomplete");
        assert_eq!(r.unmasked, lens[k]);
        assert!(r.forwards > 0);
    }
}

#[test]
fn round_robin_fairness_bounds_step_gaps() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<usize> = SessionPool::new().with_trace();
    let reqs = mixed_requests();
    let n = reqs.len();
    for (i, r) in reqs.into_iter().enumerate() {
        let s = DecodeSession::new(&sim, cfg.clone(), &r.prompt, r.gen_len)
            .unwrap();
        pool.admit(r.id, i, s);
    }
    let mut finished = 0;
    while !pool.is_empty() {
        finished += pool.step_round(&sim, &params).len();
    }
    assert_eq!(finished, n);

    // fairness: between two consecutive steps of a session, every other
    // session steps at most once (strict round-robin in admission order)
    let trace = pool.trace();
    assert!(!trace.is_empty());
    for s in 0..n as u64 {
        let occurrences: Vec<usize> = trace
            .iter()
            .enumerate()
            .filter(|(_, &x)| x == s)
            .map(|(i, _)| i)
            .collect();
        assert!(!occurrences.is_empty(), "session {s} never stepped");
        for w in occurrences.windows(2) {
            let gap = &trace[w[0] + 1..w[1]];
            assert!(gap.len() <= n - 1,
                    "session {s} starved for {} steps", gap.len());
            let mut seen = std::collections::HashSet::new();
            for &other in gap {
                assert!(seen.insert(other),
                        "session {other} stepped twice between steps of {s}");
            }
        }
    }
}

#[test]
fn width_one_pool_matches_sequential_batch1_token_for_token() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let reference = sequential_reference(&sim, &params);

    // max_concurrent_sessions = 1: admit the next request only when the
    // pool is empty — exactly the classic batch=1 engine-worker loop
    let mut queue: std::collections::VecDeque<InterleavedRequest> =
        mixed_requests().into();
    let mut pool: SessionPool<()> = SessionPool::new();
    let mut results: Vec<(String, GenResult)> = Vec::new();
    while !queue.is_empty() || !pool.is_empty() {
        if pool.is_empty() {
            let r = queue.pop_front().unwrap();
            let s =
                DecodeSession::new(&sim, cfg.clone(), &r.prompt, r.gen_len)
                    .unwrap();
            pool.admit(r.id, (), s);
        }
        for f in pool.step_round(&sim, &params) {
            results.push((f.id, f.result.unwrap()));
        }
    }

    assert_eq!(results.len(), reference.len());
    for ((id_a, a), (id_b, b)) in results.iter().zip(&reference) {
        assert_eq!(id_a, id_b);
        assert_eq!(a.tokens, b.tokens, "{id_a}: tokens diverged");
        assert_eq!(a.forwards, b.forwards, "{id_a}: forwards diverged");
        assert_eq!(a.rounds, b.rounds, "{id_a}: rounds diverged");
        assert_eq!(a.mix.full_forwards, b.mix.full_forwards, "{id_a}");
        assert_eq!(a.mix.window_forwards, b.mix.window_forwards, "{id_a}");
    }
}

#[test]
fn interleaving_width_does_not_change_any_request() {
    // a session's decode trajectory only depends on its own state, so the
    // fully interleaved pool must agree with the sequential reference too
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let reference = sequential_reference(&sim, &params);
    let interleaved =
        run_interleaved(&sim, &test_cfg(), &params, None, mixed_requests())
            .unwrap();
    for ((id_a, a), (id_b, b)) in interleaved.iter().zip(&reference) {
        assert_eq!(id_a, id_b);
        assert_eq!(a.tokens, b.tokens, "{id_a}: interleaving changed output");
        assert_eq!(a.forwards, b.forwards, "{id_a}");
    }
}

#[test]
fn per_session_failure_does_not_poison_the_pool() {
    // a prompt longer than s_max - gen_len can't even build a session;
    // build a valid pool and kill one session by exhausting its progress
    // budget is hard to trigger deterministically, so instead check the
    // retirement path with a session that finishes immediately alongside
    // long-running ones: the pool keeps stepping the survivors.
    let sim = SimBackend::new(5);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<usize> = SessionPool::new();
    for (i, gen_len) in [32usize, 128].into_iter().enumerate() {
        let s = DecodeSession::new(&sim, cfg.clone(), &prompt_for(i),
                                   gen_len)
            .unwrap();
        pool.admit(format!("r{i}"), i, s);
    }
    let mut retired = Vec::new();
    let mut rounds = 0;
    while !pool.is_empty() {
        retired.extend(pool.step_round(&sim, &params));
        rounds += 1;
        assert!(rounds < 4096);
    }
    assert_eq!(retired.len(), 2);
    // the short request retires first, the long one keeps running
    assert_eq!(retired[0].id, "r0");
    assert_eq!(retired[1].id, "r1");
    assert!(retired.iter().all(|f| f.result.is_ok()));
}

#[test]
fn step_round_coalesces_same_shape_rounds_into_one_batched_call() {
    let sim = SimBackend::new(31);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<()> = SessionPool::new();
    for k in 0..3 {
        let s =
            DecodeSession::new(&sim, cfg.clone(), &prompt_for(k), 64).unwrap();
        pool.admit(format!("r{k}"), (), s);
    }
    // round 1: three prompt prefills share (exec, s_max) -> one B=3 call
    pool.step_round(&sim, &params);
    assert_eq!(sim.prefill_batch_calls(), 1, "prefills must coalesce");
    assert_eq!(sim.max_prefill_batch(), 3);
    // round 2: three same-shape windowed rounds -> one B=3 call
    pool.step_round(&sim, &params);
    assert_eq!(sim.window_batch_calls(), 1, "windows must coalesce");
    assert_eq!(sim.max_window_batch(), 3);
}

/// Acceptance: a pool can mix `{D3llm, Ar, Spec}` sessions, same-shape
/// rounds batch (B>1), and every per-session output is bit-identical to
/// the single-session B=1 path on the same sim seed.
#[test]
fn mixed_strategy_pool_matches_b1_bit_for_bit() {
    let seed = 23u64;
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let draft = vec![0.25f32; 8];
    let mk = |s: Strategy| {
        let mut c = DecodeCfg::preset(s);
        c.early_stop = false; // sim argmax never emits EOS by default
        c
    };
    // two d3llm sessions guarantee >= 2 runnable sessions sharing round
    // shape; ar and spec ride along with their own window shapes
    let plan: [(Strategy, usize); 5] = [
        (Strategy::D3llm, 64),
        (Strategy::D3llm, 96),
        (Strategy::Ar, 32),
        (Strategy::Ar, 48),
        (Strategy::Spec, 32),
    ];
    let reqs: Vec<InterleavedRequest> = plan
        .iter()
        .enumerate()
        .map(|(k, &(s, gen_len))| InterleavedRequest {
            id: format!("m{k}"),
            prompt: prompt_for(k),
            gen_len,
            cfg: Some(mk(s)),
        })
        .collect();
    let pooled = run_interleaved(&sim, &test_cfg(), &params, Some(&draft),
                                 reqs)
        .unwrap();
    assert_eq!(pooled.len(), plan.len());
    assert!(sim.window_batch_calls() >= 1,
            "no decode_window_batch call was issued");
    assert!(sim.max_window_batch() >= 2,
            "same-shape rounds were not coalesced into B>1");
    assert!(sim.max_prefill_batch() >= 2,
            "same-shape prefills were not coalesced into B>1");

    // B=1 reference: each request alone through `generate` on a fresh
    // sim with the same seed (the sim is a pure function of the seed and
    // the call inputs, so this is the exact single-session path)
    let ref_sim = SimBackend::new(seed);
    for (k, (id, r)) in pooled.iter().enumerate() {
        let (strategy, gen_len) = plan[k];
        let reference = decode::generate(&ref_sim, &mk(strategy), &params,
                                         Some(&draft), &prompt_for(k),
                                         gen_len)
            .unwrap();
        assert_eq!(r.tokens, reference.tokens,
                   "{id}: batched pool diverged from B=1");
        assert_eq!(r.forwards, reference.forwards, "{id}");
        assert_eq!(r.draft_forwards, reference.draft_forwards, "{id}");
        assert_eq!(r.rounds, reference.rounds, "{id}");
        // interleaved sessions must report their own wall time now
        assert!(r.wall_secs > 0.0, "{id}: wall_secs not recorded");
        assert_eq!(r.tokens.len(), gen_len, "{id}: incomplete decode");
    }
}

// ---------------------------------------------------------------------
// Per-group fallback isolation: a session whose paged gather fails
// mid-batch must fall back alone, without poisoning the other sessions
// of its coalesced same-shape window group (the full-forward group path
// already had this pin via `per_session_failure_does_not_poison_the_pool`).

use anyhow::Result;
use d3llm::decode::{Backend, PrefillItem, WindowItem};
use d3llm::model::exec::{DecodeOut, PrefillOut, TrainOut, TrajectoryOut};
use d3llm::model::kv_pool::{KvPoolCfg, SharedKvPool};
use d3llm::model::KvView;
use d3llm::runtime::manifest::{Constants, ModelSpec};

/// Backend whose *paged* read path is broken: a windowed forward against
/// a page-table view fails, and a batched call containing one poisons
/// the whole batched call (exactly the failure mode the scheduler's
/// per-session fallback exists for). Dense sessions are untouched.
struct PagedGatherFails<'a> {
    inner: &'a SimBackend,
}

impl Backend for PagedGatherFails<'_> {
    fn constants(&self) -> &Constants {
        self.inner.constants()
    }

    fn model_spec(&self, name: &str) -> Result<&ModelSpec> {
        self.inner.model_spec(name)
    }

    fn prefill(&self, exec: &str, params: &[f32], tokens: &[i32],
               valid: &[f32]) -> Result<PrefillOut> {
        self.inner.prefill(exec, params, tokens, valid)
    }

    fn decode_window(&self, exec: &str, params: &[f32], win_tokens: &[i32],
                     win_pos: &[i32], win_valid: &[f32], cache: &dyn KvView)
                     -> Result<DecodeOut> {
        if cache.page_args().is_some() {
            anyhow::bail!("injected: paged gather failed");
        }
        self.inner
            .decode_window(exec, params, win_tokens, win_pos, win_valid,
                           cache)
    }

    fn prefill_batch(&self, params: &[f32], items: &[PrefillItem<'_>])
                     -> Result<Vec<PrefillOut>> {
        self.inner.prefill_batch(params, items)
    }

    fn decode_window_batch(&self, params: &[f32], items: &[WindowItem<'_>])
                           -> Result<Vec<DecodeOut>> {
        if items.iter().any(|it| it.cache.page_args().is_some()) {
            anyhow::bail!("injected: batched paged gather failed");
        }
        self.inner.decode_window_batch(params, items)
    }

    #[allow(clippy::too_many_arguments)]
    fn train_step(&self, exec: &str, params: &[f32], m: &[f32], v: &[f32],
                  step: i32, tokens: &[i32], labels: &[i32],
                  loss_mask: &[f32], attn_valid: &[f32], lr: f32,
                  ent_weight: f32) -> Result<TrainOut> {
        self.inner.train_step(exec, params, m, v, step, tokens, labels,
                              loss_mask, attn_valid, lr, ent_weight)
    }

    fn trajectory(&self, params: &[f32], tokens: &[i32], attn_valid: &[f32],
                  gen_mask: &[f32]) -> Result<TrajectoryOut> {
        self.inner.trajectory(params, tokens, attn_valid, gen_mask)
    }
}

#[test]
fn paged_gather_failure_falls_back_alone_in_its_window_group() {
    let sim = SimBackend::new(77);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let (pa, pb, pc) = (prompt_for(1), prompt_for(2), prompt_for(3));

    // solo references on the unwrapped backend (dense sessions)
    let ra = decode::generate(&sim, &cfg, &params, None, &pa, 64).unwrap();
    let rc = decode::generate(&sim, &cfg, &params, None, &pc, 64).unwrap();

    let backend = PagedGatherFails { inner: &sim };
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let kv = SharedKvPool::new(KvPoolCfg {
        layers: spec.n_layers,
        d_kv: spec.d_kv,
        s_max: c.s_max,
        page_rows: c.block,
        budget_bytes: 1 << 20,
    });

    // one coalesced same-shape group: all d3llm, same window executable;
    // B is the only paged session and the only one that may fail
    let mut pool: SessionPool<usize> = SessionPool::new();
    pool.admit("a".into(), 0,
               DecodeSession::new(&backend, cfg.clone(), &pa, 64).unwrap());
    pool.admit("b".into(), 1,
               DecodeSession::with_pool(&backend, cfg.clone(), &pb, 64,
                                        None, &kv)
                   .unwrap());
    pool.admit("c".into(), 2,
               DecodeSession::new(&backend, cfg.clone(), &pc, 64).unwrap());

    let mut results: Vec<Option<Result<GenResult>>> =
        (0..3).map(|_| None).collect();
    while !pool.is_empty() {
        for f in pool.step_round(&backend, &params) {
            results[f.tag] = Some(f.result);
        }
    }
    let got_a = results[0].take().unwrap().expect("dense A must survive");
    let err_b = results[1].take().unwrap()
        .expect_err("paged B must fail alone");
    let got_c = results[2].take().unwrap().expect("dense C must survive");
    assert!(format!("{err_b:#}").contains("paged gather"),
            "unexpected failure: {err_b:#}");
    assert_eq!(got_a.tokens, ra.tokens, "A was poisoned by B's failure");
    assert_eq!(got_a.forwards, ra.forwards, "A forwards diverged");
    assert_eq!(got_c.tokens, rc.tokens, "C was poisoned by B's failure");
    assert_eq!(got_c.forwards, rc.forwards, "C forwards diverged");
    // the failed session released its pages and reservation on retire
    let u = kv.usage();
    assert_eq!(u.in_use + u.reserved, 0, "B leaked pool pages");
}

// ---------------------------------------------------------------------
// EDF scheduling + preemption-by-pausing (deadline-aware serving). All
// deadlines live on the pool's virtual `set_now_ms` clock, so these runs
// are fully deterministic.

#[test]
fn edf_width_pressure_runs_earliest_deadline_first() {
    let sim = SimBackend::new(13);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    // adversarial admission order: deadlines inverted (latest admitted
    // first) plus one deadline-free rider
    let mut pool: SessionPool<usize> =
        SessionPool::new().with_trace().with_round_width(1);
    pool.set_now_ms(0);
    let deadlines = [Some(30_000u64), Some(20_000), Some(10_000), None];
    for (i, dl) in deadlines.into_iter().enumerate() {
        let s = DecodeSession::new(&sim, cfg.clone(), &prompt_for(i), 32)
            .unwrap();
        pool.admit_deadline(format!("r{i}"), i, s, dl);
    }
    let mut order = Vec::new();
    let mut results: Vec<Option<GenResult>> =
        (0..4).map(|_| None).collect();
    while !pool.is_empty() {
        for f in pool.step_round(&sim, &params) {
            order.push(f.id.clone());
            assert!(!f.deadline_missed, "{}: the clock never advanced",
                    f.id);
            results[f.tag] = Some(f.result.unwrap());
        }
    }
    // earliest deadline drains first; the deadline-free session runs last
    assert_eq!(order, ["r2", "r1", "r0", "r3"]);
    assert!(pool.preempted_total > 0, "width 1 must have paused losers");
    assert_eq!(pool.deadline_miss_total, 0);
    // pause bookkeeping surfaces in the results
    assert_eq!(results[2].take().unwrap().paused_rounds, 0,
               "the most urgent session must never pause");
    assert!(results[3].take().unwrap().paused_rounds > 0,
            "the deadline-free session was never paused");
}

#[test]
fn overdue_sessions_yield_their_slot_to_meetable_work() {
    let sim = SimBackend::new(17);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<usize> =
        SessionPool::new().with_round_width(1);
    for (i, dl) in [Some(50u64), Some(60_000)].into_iter().enumerate() {
        let s = DecodeSession::new(&sim, cfg.clone(), &prompt_for(i), 32)
            .unwrap();
        pool.admit_deadline(format!("r{i}"), i, s, dl);
    }
    // the clock is already past r0's deadline: EDF alone would run r0
    // first, but an overdue session has nothing left to win — r1 (still
    // meetable) takes every round slot until it retires
    pool.set_now_ms(100);
    let mut order = Vec::new();
    let mut missed = Vec::new();
    while !pool.is_empty() {
        for f in pool.step_round(&sim, &params) {
            order.push(f.id.clone());
            missed.push(f.deadline_missed);
        }
    }
    assert_eq!(order, ["r1", "r0"]);
    assert_eq!(missed, [false, true]);
    assert_eq!(pool.deadline_miss_total, 1);
}

#[test]
fn preempted_sessions_resume_bit_identical() {
    let seed = 29u64;
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    // solo reference for the session that will be paused mid-decode (the
    // sim is a pure function of the seed and the call inputs)
    let ref_sim = SimBackend::new(seed);
    let reference = decode::generate(&ref_sim, &cfg, &params, None,
                                     &prompt_for(4), 64)
        .unwrap();

    let mut pool: SessionPool<usize> =
        SessionPool::new().with_round_width(1);
    pool.set_now_ms(0);
    // the urgent job wins every round slot until it retires; the
    // deadline-free job pauses the whole time, then resumes
    pool.admit_deadline(
        "urgent".into(), 0,
        DecodeSession::new(&sim, cfg.clone(), &prompt_for(3), 32).unwrap(),
        Some(500),
    );
    pool.admit_deadline(
        "paused".into(), 1,
        DecodeSession::new(&sim, cfg.clone(), &prompt_for(4), 64).unwrap(),
        None,
    );
    let mut results: Vec<Option<GenResult>> = vec![None, None];
    while !pool.is_empty() {
        for f in pool.step_round(&sim, &params) {
            results[f.tag] = Some(f.result.unwrap());
        }
    }
    let paused = results[1].take().unwrap();
    assert!(paused.paused_rounds > 0, "session was never actually paused");
    assert_eq!(paused.tokens, reference.tokens,
               "pause/resume changed the decode trajectory");
    assert_eq!(paused.forwards, reference.forwards,
               "pause/resume changed the forward count");
    assert_eq!(paused.rounds, reference.rounds,
               "paused rounds leaked into the session's own round count");
}

// ---------------------------------------------------------------------
// Multi-worker fleet: prefix-affinity placement via the router core must
// never change what any single request decodes — routing is a pure
// performance decision. The fleet here is threadless (one pool + kv pool
// per replica, placed by `RouterCore`), so the runs stay deterministic.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};

use d3llm::coordinator::protocol::{GenRequest, SloClass};
use d3llm::coordinator::router::{Router, RouterCore};
use d3llm::coordinator::Job;
use d3llm::model::kv_pool::prefix_routing_key;

/// 36 shared tokens per family (>= one full 32-row page, the routing
/// key) plus a 4-token member-unique tail.
fn family_prompt(family: usize, member: usize) -> Vec<i32> {
    let mut p: Vec<i32> =
        (0..36).map(|i| 5 + ((i * 7 + family * 13) % 80) as i32).collect();
    p.extend((0..4).map(|j| 5 + ((j + 11 * member + family) % 80) as i32));
    p
}

#[test]
fn four_replica_fleet_matches_single_replica_reference() {
    let seed = 43u64;
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let mk_kv = || {
        SharedKvPool::new(KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block,
            budget_bytes: 1 << 20,
        })
    };
    let reqs: Vec<(String, Vec<i32>)> = (0..8)
        .flat_map(|fam| {
            (0..2).map(move |m| {
                (format!("f{fam}m{m}"), family_prompt(fam, m))
            })
        })
        .collect();

    // 1-replica reference: every request in one pool on one kv pool
    let ref_sim = SimBackend::new(seed);
    let ref_kv = mk_kv();
    let mut ref_pool: SessionPool<()> = SessionPool::new();
    for (id, prompt) in &reqs {
        ref_pool.admit(id.clone(), (),
                       DecodeSession::with_pool(&ref_sim, cfg.clone(),
                                                prompt, 32, None, &ref_kv)
                           .unwrap());
    }
    let mut reference: HashMap<String, GenResult> = HashMap::new();
    while !ref_pool.is_empty() {
        for f in ref_pool.step_round(&ref_sim, &params) {
            reference.insert(f.id, f.result.unwrap());
        }
    }

    // 4-replica fleet: the same requests, placed by prefix affinity
    let core = RouterCore::new(4, 64);
    let kvs: Vec<SharedKvPool> = (0..4).map(|_| mk_kv()).collect();
    let mut pools: Vec<SessionPool<()>> = kvs
        .iter()
        .map(|kv| SessionPool::new().with_kv_pool(kv.clone()))
        .collect();
    let mut family_home: HashMap<u64, usize> = HashMap::new();
    for (id, prompt) in &reqs {
        let geo = decode::kv_admission_geometry(&cfg, &c, prompt.len(), 0);
        let key = prefix_routing_key(&geo.prefix_tag, spec.n_layers,
                                     spec.d_kv, c.block, prompt,
                                     geo.prefix_rows)
            .expect("a 40-token prompt spans a full page");
        let r = core.place(Some(key), None).expect("live fleet").replica();
        // prefix affinity: the same key homes on the same replica, always
        assert_eq!(*family_home.entry(key).or_insert(r), r,
                   "{id}: family split across replicas");
        pools[r].admit(id.clone(), (),
                       DecodeSession::with_pool(&sim, cfg.clone(), prompt,
                                                32, None, &kvs[r])
                           .unwrap());
    }
    assert_eq!(core.affinity_hits.load(Ordering::Relaxed), 16,
               "an idle keyed fleet must place by affinity only");
    assert_eq!(core.cold_placements.load(Ordering::Relaxed), 0);
    assert!(family_home.values().collect::<HashSet<_>>().len() >= 2,
            "HRW degenerated to a single replica");

    let mut fleet: HashMap<String, GenResult> = HashMap::new();
    for pool in &mut pools {
        while !pool.is_empty() {
            for f in pool.step_round(&sim, &params) {
                fleet.insert(f.id, f.result.unwrap());
            }
        }
    }
    assert_eq!(fleet.len(), reference.len(), "the fleet lost requests");
    for (id, r) in &reference {
        let got = fleet.get(id)
            .unwrap_or_else(|| panic!("{id} lost by the fleet"));
        assert_eq!(got.tokens, r.tokens,
                   "{id}: fleet diverged from the 1-replica reference");
        assert_eq!(got.forwards, r.forwards, "{id}: forwards diverged");
    }
}

fn mk_job(id: &str, reply: &mpsc::Sender<String>) -> Job {
    Job {
        req: GenRequest {
            id: id.into(),
            prompt: String::new(),
            gen_len: Some(32),
            priority: 0,
            strategy: None,
            slo: SloClass::Standard,
            deadline_ms: None,
        },
        reply: reply.clone(),
    }
}

#[test]
fn replica_kill_drains_queued_jobs_to_survivors() {
    let core = Arc::new(RouterCore::new(2, 8));
    let (tx0, rx0) = mpsc::channel::<Job>();
    let (tx1, rx1) = mpsc::channel::<Job>();
    let rt = Router::new(core.clone(), vec![tx0, tx1]);
    let (reply_tx, reply_rx) = mpsc::channel::<String>();

    // key-less placement is least-loaded; with idle gauges the tie breaks
    // to replica 0, so the whole backlog lands on the replica we kill
    for k in 0..4 {
        rt.dispatch(None, None, mk_job(&format!("q{k}"), &reply_tx))
            .expect("live fleet");
    }
    assert_eq!(core.cold_placements.load(Ordering::Relaxed), 4);

    // the replica dies. This is the worker wrapper's exact sequence:
    // mark it dead first (re-routes must not bounce back), then salvage
    // the queued backlog and re-route it to the survivors.
    rt.drop_replica(0);
    let mut salvaged = Vec::new();
    while let Ok(job) = rx0.try_recv() {
        salvaged.push(job);
    }
    assert_eq!(salvaged.len(), 4, "backlog did not land on replica 0");
    for job in salvaged {
        assert!(rt.reroute(job).is_ok(),
                "the survivor must absorb the backlog");
    }
    // intake after the death routes straight to the survivor
    rt.dispatch(None, None, mk_job("q4", &reply_tx)).expect("live fleet");

    let mut got: Vec<String> = Vec::new();
    while let Ok(job) = rx1.try_recv() {
        // the reply handle survived the re-route: the survivor can still
        // answer the original connection
        job.reply.send(format!("done {}", job.req.id)).unwrap();
        got.push(job.req.id);
    }
    got.sort();
    assert_eq!(got, ["q0", "q1", "q2", "q3", "q4"],
               "a queued request was lost in the drain");
    for _ in 0..5 {
        reply_rx.recv().expect("a reply connection was dropped");
    }
    assert_eq!(core.jobs_rerouted.load(Ordering::Relaxed), 4);
    assert_eq!(core.replica_deaths.load(Ordering::Relaxed), 1);
    assert_eq!(core.alive_count(), 1);

    // fleet-wide death: the job comes back so the caller can still send
    // an error reply instead of hanging the connection
    rt.drop_replica(1);
    let job = rt.reroute(mk_job("q5", &reply_tx))
        .expect_err("a dead fleet cannot absorb work");
    assert_eq!(job.req.id, "q5");
}

#[test]
fn drain_sessions_releases_paged_pages_and_reports_ids() {
    let sim = SimBackend::new(41);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let kv = SharedKvPool::new(KvPoolCfg {
        layers: spec.n_layers,
        d_kv: spec.d_kv,
        s_max: c.s_max,
        page_rows: c.block,
        budget_bytes: 1 << 20,
    });
    let mut pool: SessionPool<usize> =
        SessionPool::new().with_kv_pool(kv.clone());
    for i in 0..2 {
        pool.admit(format!("r{i}"), i,
                   DecodeSession::with_pool(&sim, cfg.clone(),
                                            &prompt_for(i), 32, None, &kv)
                       .unwrap());
    }
    pool.step_round(&sim, &params); // prefill: sessions now hold pages
    assert!(kv.usage().in_use > 0, "prefill installed no pages");

    let drained = pool.drain_sessions();
    assert_eq!(drained.iter().map(|(id, _)| id.as_str()).collect::<Vec<_>>(),
               ["r0", "r1"]);
    assert_eq!(drained.iter().map(|(_, tag)| *tag).collect::<Vec<_>>(),
               [0, 1]);
    assert!(pool.is_empty());
    let u = kv.usage();
    assert_eq!(u.in_use + u.reserved, 0, "drain leaked pool pages");
}

// ---------------------------------------------------------------------
// Preemption spill: a session paused past `spill_after_rounds` gives its
// paged KV back to the pool and re-prefills the lost rows on resume —
// the decode trajectory must not notice.

#[test]
fn spilled_sessions_resume_bit_identical_and_account_pages() {
    let seed = 37u64;
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let mk_kv = || {
        SharedKvPool::new(KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block,
            budget_bytes: 1 << 20,
        })
    };

    // solo paged reference (the sim is a pure function of seed + inputs)
    let ref_sim = SimBackend::new(seed);
    let ref_kv = mk_kv();
    let mut ref_pool: SessionPool<()> = SessionPool::new();
    ref_pool.admit("ref".into(), (),
                   DecodeSession::with_pool(&ref_sim, cfg.clone(),
                                            &prompt_for(4), 64, None,
                                            &ref_kv)
                       .unwrap());
    let mut reference = None;
    while !ref_pool.is_empty() {
        for f in ref_pool.step_round(&ref_sim, &params) {
            reference = Some(f.result.unwrap());
        }
    }
    let reference = reference.unwrap();

    let kv = mk_kv();
    let mut pool: SessionPool<usize> =
        SessionPool::new().with_round_width(1).with_kv_pool(kv.clone());
    pool.set_spill_after_rounds(2);
    pool.set_now_ms(0);
    pool.admit_deadline(
        "a".into(), 0,
        DecodeSession::with_pool(&sim, cfg.clone(), &prompt_for(4), 64,
                                 None, &kv)
            .unwrap(),
        None,
    );
    // a runs alone first (prefill + one window), so it holds pool pages
    // by the time the urgent arrival preempts it
    for _ in 0..2 {
        pool.step_round(&sim, &params);
    }
    pool.admit_deadline(
        "b".into(), 1,
        DecodeSession::with_pool(&sim, cfg.clone(), &prompt_for(3), 32,
                                 None, &kv)
            .unwrap(),
        Some(500),
    );
    let mut results: Vec<Option<GenResult>> = vec![None, None];
    while !pool.is_empty() {
        for f in pool.step_round(&sim, &params) {
            results[f.tag] = Some(f.result.unwrap());
        }
    }
    let a = results[0].take().unwrap();
    assert!(a.paused_rounds > 0, "a was never actually preempted");
    let ks = kv.stats();
    assert!(ks.pages_spilled > 0, "the paused session never spilled");
    assert!(ks.pages_reprefilled <= ks.pages_spilled,
            "restore rebuilt more pages than were ever spilled");
    // forwards differ by design (the restore prefill is extra work);
    // the emitted tokens must not
    assert_eq!(a.tokens, reference.tokens,
               "spill/restore changed the decode trajectory");
    let u = kv.usage();
    assert_eq!(u.in_use + u.reserved, 0, "spill path leaked pool pages");
}

#[test]
fn width_limited_deadline_free_pool_degrades_to_round_robin() {
    let sim = SimBackend::new(19);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<usize> =
        SessionPool::new().with_trace().with_round_width(2);
    for i in 0..4 {
        let s = DecodeSession::new(&sim, cfg.clone(), &prompt_for(i), 32)
            .unwrap();
        pool.admit(format!("r{i}"), i, s);
    }
    let mut finished = 0;
    while !pool.is_empty() {
        finished += pool.step_round(&sim, &params).len();
    }
    assert_eq!(finished, 4, "width pressure must not strand sessions");
    // least-recently-stepped tie rotation: with no deadlines, width-2
    // rounds alternate session pairs in admission order
    assert!(pool.trace().len() >= 8);
    assert_eq!(&pool.trace()[..8], &[0u64, 1, 2, 3, 0, 1, 2, 3]);
    assert!(pool.preempted_total > 0);
}
