//! Deterministic scheduler tests over the SimBackend: no artifacts, no
//! PJRT, fully reproducible.
//!
//!   * N queued requests with mixed gen_lens all complete;
//!   * round-robin fairness bounds per-session step gaps;
//!   * `max_concurrent_sessions = 1` reproduces the classic batch=1
//!     sequential decode token-for-token (and so does any pool width,
//!     since a session's trajectory is schedule-independent).

use d3llm::coordinator::scheduler::{run_interleaved, InterleavedRequest,
                                    SessionPool};
use d3llm::decode::multi_block::decode_multi_block;
use d3llm::decode::{DecodeCfg, DecodeSession, GenResult, SimBackend,
                    Strategy};

fn test_cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false; // sim argmax never emits EOS by default
    cfg
}

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(8 + k % 5)).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

/// The mixed workload: 8 requests spanning every gen_len the geometry
/// supports.
fn mixed_requests() -> Vec<InterleavedRequest> {
    let lens = [32usize, 128, 64, 96, 32, 128, 96, 64];
    lens.iter()
        .enumerate()
        .map(|(k, &gen_len)| InterleavedRequest {
            id: format!("r{k}"),
            prompt: prompt_for(k),
            gen_len,
        })
        .collect()
}

fn sequential_reference(sim: &SimBackend, params: &[f32])
                        -> Vec<(String, GenResult)> {
    mixed_requests()
        .into_iter()
        .map(|r| {
            let cfg = test_cfg();
            let out =
                decode_multi_block(sim, &cfg, params, &r.prompt, r.gen_len)
                    .unwrap();
            (r.id, out)
        })
        .collect()
}

#[test]
fn mixed_gen_lens_all_complete() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let results =
        run_interleaved(&sim, &test_cfg(), &params, mixed_requests())
            .unwrap();
    assert_eq!(results.len(), 8);
    let lens = [32usize, 128, 64, 96, 32, 128, 96, 64];
    for (k, (id, r)) in results.iter().enumerate() {
        assert_eq!(id, &format!("r{k}"), "input order preserved");
        assert_eq!(r.tokens.len(), lens[k], "{id} incomplete");
        assert_eq!(r.unmasked, lens[k]);
        assert!(r.forwards > 0);
    }
}

#[test]
fn round_robin_fairness_bounds_step_gaps() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<usize> = SessionPool::new().with_trace();
    let reqs = mixed_requests();
    let n = reqs.len();
    for (i, r) in reqs.into_iter().enumerate() {
        let s = DecodeSession::new(&sim, cfg.clone(), &r.prompt, r.gen_len)
            .unwrap();
        pool.admit(r.id, i, s);
    }
    let mut finished = 0;
    while !pool.is_empty() {
        finished += pool.step_round(&sim, &params).len();
    }
    assert_eq!(finished, n);

    // fairness: between two consecutive steps of a session, every other
    // session steps at most once (strict round-robin in admission order)
    let trace = pool.trace();
    assert!(!trace.is_empty());
    for s in 0..n as u64 {
        let occurrences: Vec<usize> = trace
            .iter()
            .enumerate()
            .filter(|(_, &x)| x == s)
            .map(|(i, _)| i)
            .collect();
        assert!(!occurrences.is_empty(), "session {s} never stepped");
        for w in occurrences.windows(2) {
            let gap = &trace[w[0] + 1..w[1]];
            assert!(gap.len() <= n - 1,
                    "session {s} starved for {} steps", gap.len());
            let mut seen = std::collections::HashSet::new();
            for &other in gap {
                assert!(seen.insert(other),
                        "session {other} stepped twice between steps of {s}");
            }
        }
    }
}

#[test]
fn width_one_pool_matches_sequential_batch1_token_for_token() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let reference = sequential_reference(&sim, &params);

    // max_concurrent_sessions = 1: admit the next request only when the
    // pool is empty — exactly the classic batch=1 engine-worker loop
    let mut queue: std::collections::VecDeque<InterleavedRequest> =
        mixed_requests().into();
    let mut pool: SessionPool<()> = SessionPool::new();
    let mut results: Vec<(String, GenResult)> = Vec::new();
    while !queue.is_empty() || !pool.is_empty() {
        if pool.is_empty() {
            let r = queue.pop_front().unwrap();
            let s =
                DecodeSession::new(&sim, cfg.clone(), &r.prompt, r.gen_len)
                    .unwrap();
            pool.admit(r.id, (), s);
        }
        for f in pool.step_round(&sim, &params) {
            results.push((f.id, f.result.unwrap()));
        }
    }

    assert_eq!(results.len(), reference.len());
    for ((id_a, a), (id_b, b)) in results.iter().zip(&reference) {
        assert_eq!(id_a, id_b);
        assert_eq!(a.tokens, b.tokens, "{id_a}: tokens diverged");
        assert_eq!(a.forwards, b.forwards, "{id_a}: forwards diverged");
        assert_eq!(a.rounds, b.rounds, "{id_a}: rounds diverged");
        assert_eq!(a.mix.full_forwards, b.mix.full_forwards, "{id_a}");
        assert_eq!(a.mix.window_forwards, b.mix.window_forwards, "{id_a}");
    }
}

#[test]
fn interleaving_width_does_not_change_any_request() {
    // a session's decode trajectory only depends on its own state, so the
    // fully interleaved pool must agree with the sequential reference too
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let reference = sequential_reference(&sim, &params);
    let interleaved =
        run_interleaved(&sim, &test_cfg(), &params, mixed_requests())
            .unwrap();
    for ((id_a, a), (id_b, b)) in interleaved.iter().zip(&reference) {
        assert_eq!(id_a, id_b);
        assert_eq!(a.tokens, b.tokens, "{id_a}: interleaving changed output");
        assert_eq!(a.forwards, b.forwards, "{id_a}");
    }
}

#[test]
fn per_session_failure_does_not_poison_the_pool() {
    // a prompt longer than s_max - gen_len can't even build a session;
    // build a valid pool and kill one session by exhausting its progress
    // budget is hard to trigger deterministically, so instead check the
    // retirement path with a session that finishes immediately alongside
    // long-running ones: the pool keeps stepping the survivors.
    let sim = SimBackend::new(5);
    let params = vec![0.5f32; 8];
    let cfg = test_cfg();
    let mut pool: SessionPool<usize> = SessionPool::new();
    for (i, gen_len) in [32usize, 128].into_iter().enumerate() {
        let s = DecodeSession::new(&sim, cfg.clone(), &prompt_for(i),
                                   gen_len)
            .unwrap();
        pool.admit(format!("r{i}"), i, s);
    }
    let mut retired = Vec::new();
    let mut rounds = 0;
    while !pool.is_empty() {
        retired.extend(pool.step_round(&sim, &params));
        rounds += 1;
        assert!(rounds < 4096);
    }
    assert_eq!(retired.len(), 2);
    // the short request retires first, the long one keeps running
    assert_eq!(retired[0].id, "r0");
    assert_eq!(retired[1].id, "r1");
    assert!(retired.iter().all(|f| f.result.is_ok()));
}
