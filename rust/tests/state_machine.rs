//! Deterministic test harness for the decode state machine.
//!
//! Drives `unmask_round` directly with synthetic head statistics (no
//! engine, no artifacts) and whole sessions over the `SimBackend`, and
//! checks the three contract properties:
//!
//!   * progress: a round with any visible masked position in an active
//!     block unmasks at least one token (no wasted forwards);
//!   * containment: a round never writes outside the active blocks'
//!     ranges (and never outside the restrict span / stats window);
//!   * ordering: block states only move forward along
//!     Inactive -> Activated -> FullyActivated -> Stabilizing(n) ->
//!     Completed, with the stabilizing counter non-increasing.

use d3llm::decode::multi_block::{unmask_round, BlockState, RoundStatsOwned};
use d3llm::decode::{DecodeCfg, DecodeSession, SeqState, SessionPhase,
                    SimBackend, Strategy};
use d3llm::tokenizer::MASK;
use d3llm::util::rng::Rng;

fn prop(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x51D3).wrapping_add(9));
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!("property `{name}` failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn state_rank(s: &BlockState) -> u8 {
    match s {
        BlockState::Inactive => 0,
        BlockState::Activated => 1,
        BlockState::FullyActivated => 2,
        BlockState::Stabilizing(_) => 3,
        BlockState::Completed => 4,
    }
}

/// Random block-state vector with at least one active block.
fn random_states(rng: &mut Rng, nb: usize) -> Vec<BlockState> {
    let mut states: Vec<BlockState> = (0..nb)
        .map(|_| match rng.usize(5) {
            0 => BlockState::Inactive,
            1 => BlockState::Activated,
            2 => BlockState::FullyActivated,
            3 => BlockState::Stabilizing(1 + rng.usize(2)),
            _ => BlockState::Completed,
        })
        .collect();
    let k = rng.usize(nb);
    states[k] = if rng.bool(0.5) {
        BlockState::Activated
    } else {
        BlockState::FullyActivated
    };
    states
}

/// Random sequence state: masked gen region with a random decoded subset.
fn random_seq(rng: &mut Rng, nb: usize, block: usize) -> SeqState {
    let prompt_len = 1 + rng.usize(64);
    let prompt: Vec<i32> = (0..prompt_len)
        .map(|_| 5 + rng.usize(90) as i32)
        .collect();
    let mut st = SeqState::new(&prompt, nb * block, block, 384);
    for j in 0..nb * block {
        if rng.bool(0.5) {
            st.tokens[prompt_len + j] = 5 + rng.usize(90) as i32;
        }
    }
    st
}

/// Synthetic full-sequence head statistics.
fn random_full_stats(rng: &mut Rng, s_max: usize) -> RoundStatsOwned {
    RoundStatsOwned {
        argmax: (0..s_max).map(|_| 5 + rng.usize(123) as i32).collect(),
        conf: (0..s_max).map(|_| rng.f32()).collect(),
        entropy: (0..s_max).map(|_| rng.f32() * 4.85).collect(),
        w_lo: 0,
        w_hi: s_max,
        absolute: true,
    }
}

#[test]
fn prop_round_makes_progress_and_stays_in_active_ranges() {
    prop("progress+containment", 300, |rng| {
        let block = 32;
        let nb = 1 + rng.usize(4);
        let mut st = random_seq(rng, nb, block);
        let mut states = random_states(rng, nb);
        let cfg = DecodeCfg::preset(Strategy::D3llm);
        let stats = random_full_stats(rng, st.s_max);
        let restrict = if rng.bool(0.5) {
            None
        } else {
            let lo = rng.usize(nb);
            Some((lo, lo + 1 + rng.usize(nb - lo)))
        };
        let (b_lo, b_hi) = restrict.unwrap_or((0, nb));

        let visible_masked: Vec<usize> = (b_lo..b_hi.min(nb))
            .filter(|&b| states[b].is_active())
            .flat_map(|b| {
                let (lo, hi) = st.block_range(b);
                lo..hi
            })
            .filter(|&p| st.tokens[p] == MASK)
            .collect();
        let before = st.tokens.clone();
        let states_before = states.clone();

        let completed =
            unmask_round(&cfg, &mut st, &mut states, &stats, restrict);

        // progress guarantee
        let unmasked_now: Vec<usize> = (0..st.tokens.len())
            .filter(|&p| before[p] == MASK && st.tokens[p] != MASK)
            .collect();
        if !visible_masked.is_empty() {
            assert!(!unmasked_now.is_empty(),
                    "no progress despite visible masked positions");
        }
        // containment: writes only at visible masked positions of active
        // blocks inside the restrict span
        for &p in &unmasked_now {
            assert!(visible_masked.contains(&p),
                    "wrote outside active range at {p}");
            assert_eq!(st.tokens[p], stats.argmax[p], "wrong token at {p}");
        }
        // non-mask positions are never rewritten
        for p in 0..st.tokens.len() {
            if before[p] != MASK {
                assert_eq!(st.tokens[p], before[p], "rewrote {p}");
            }
        }
        // state changes only: active -> Stabilizing on completion
        for b in 0..nb {
            if states[b] != states_before[b] {
                assert!(states_before[b].is_active());
                assert!(matches!(states[b], BlockState::Stabilizing(_)));
                assert!(st.block_complete(b));
                assert!(completed.contains(&b));
            }
        }
        for &b in &completed {
            assert!(st.block_complete(b), "completed block {b} has masks");
        }
    });
}

#[test]
fn prop_windowed_round_never_writes_outside_window() {
    prop("window containment", 300, |rng| {
        let block = 32;
        let nb = 2 + rng.usize(3);
        let mut st = random_seq(rng, nb, block);
        let mut states = random_states(rng, nb);
        let cfg = DecodeCfg::preset(Strategy::D3llm);
        // window over a sub-span of blocks
        let first = rng.usize(nb);
        let span = 1 + rng.usize((nb - first).min(3));
        let (w_lo, _) = st.block_range(first);
        let w_hi = st.block_range(first + span - 1).1;
        let w = w_hi - w_lo;
        let stats = RoundStatsOwned {
            argmax: (0..w).map(|_| 5 + rng.usize(123) as i32).collect(),
            conf: (0..w).map(|_| rng.f32()).collect(),
            entropy: (0..w).map(|_| rng.f32() * 4.85).collect(),
            w_lo,
            w_hi,
            absolute: false,
        };
        let before = st.tokens.clone();
        unmask_round(&cfg, &mut st, &mut states, &stats,
                     Some((first, first + span)));
        for p in 0..st.tokens.len() {
            if p < w_lo || p >= w_hi {
                assert_eq!(st.tokens[p], before[p],
                           "windowed round wrote outside [{w_lo},{w_hi}) at {p}");
            }
        }
    });
}

#[test]
fn session_block_states_only_move_forward() {
    for seed in 0..6u64 {
        for strategy in [Strategy::D3llm, Strategy::D2f] {
            let sim = SimBackend::new(100 + seed);
            let mut cfg = DecodeCfg::preset(strategy);
            cfg.early_stop = false;
            let params = vec![0.25f32; 16];
            let prompt: Vec<i32> =
                (0..12).map(|i| 5 + (i * 3 + seed as i32) % 80).collect();
            let mut session =
                DecodeSession::new(&sim, cfg, &prompt, 128).unwrap();
            let nb = session.st.n_blocks();
            let mut last_rank: Vec<u8> = session
                .block_states()
                .expect("multi-block session exposes block states")
                .iter()
                .map(state_rank)
                .collect();
            let mut last_stab: Vec<Option<usize>> = vec![None; nb];
            let mut guard = 0;
            while !session.step(&sim, &params).unwrap() {
                let states = session.block_states().unwrap();
                for b in 0..nb {
                    let r = state_rank(&states[b]);
                    assert!(
                        r >= last_rank[b],
                        "block {b} moved backwards: {} -> {r} (seed {seed})",
                        last_rank[b]
                    );
                    if let BlockState::Stabilizing(n) = states[b] {
                        if let Some(prev) = last_stab[b] {
                            assert!(n <= prev,
                                    "stabilizing counter grew on block {b}");
                        }
                        last_stab[b] = Some(n);
                    }
                    last_rank[b] = r;
                }
                guard += 1;
                assert!(guard < 4096, "session did not terminate");
            }
            assert!(session.is_done());
            assert_eq!(session.phase(), SessionPhase::Done);
        }
    }
}

#[test]
fn session_accounting_is_stable() {
    let sim = SimBackend::new(42);
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    let params = vec![0.5f32; 8];
    let prompt: Vec<i32> = (0..16).map(|i| 5 + i % 80).collect();
    let mut session = DecodeSession::new(&sim, cfg, &prompt, 96).unwrap();
    assert_eq!(session.phase(), SessionPhase::Prefill);
    assert!(session.is_runnable());

    let mut steps = 0;
    while !session.step(&sim, &params).unwrap() {
        steps += 1;
        let p = session.progress();
        assert_eq!(p.steps, steps, "steps() must count every working step");
        assert_eq!(p.rounds + 1, steps, "rounds excludes the prefill");
        assert!(p.forwards <= p.rounds, "at most one forward per round");
        assert!(p.unmasked <= p.gen_len);
        assert_eq!(session.phase(), SessionPhase::Decoding);
    }
    assert!(!session.is_runnable());
    let final_progress = session.progress();
    let r = session.finish();
    assert_eq!(r.tokens.len(), 96, "early_stop off: full region decodes");
    assert!(!r.tokens.contains(&MASK));
    assert_eq!(r.unmasked, 96);
    assert_eq!(final_progress.unmasked, 96);
    assert_eq!(r.forwards, final_progress.forwards);
    assert!(r.mix.full_forwards > 0, "d3llm must refresh");
    assert!(r.mix.window_forwards > 0);
}

#[test]
fn sim_sessions_are_reproducible() {
    let run = || {
        let sim = SimBackend::new(7);
        let mut cfg = DecodeCfg::preset(Strategy::D3llm);
        cfg.early_stop = false;
        let params = vec![0.5f32; 8];
        let prompt: Vec<i32> = (0..20).map(|i| 5 + i % 77).collect();
        let mut s = DecodeSession::new(&sim, cfg, &prompt, 64).unwrap();
        while !s.step(&sim, &params).unwrap() {}
        s.finish()
    };
    let a = run();
    let b = run();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.forwards, b.forwards);
    assert_eq!(a.rounds, b.rounds);
}
