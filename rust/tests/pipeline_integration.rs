//! Integration: the training/distillation pipeline — short real training
//! runs through the AOT train executables, trajectory extraction + cache,
//! checkpoint round-trips. Heavier than unit tests; still < 1 min total.

use d3llm::data::{main_mixture, Family};
use d3llm::model::ParamStore;
use d3llm::runtime::Engine;
use d3llm::tokenizer::Tokenizer;
use d3llm::train::{train, TrainCfg};
use d3llm::trajectory::{self, Curriculum, Recipe};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing");
        return None;
    }
    Some(Engine::load("artifacts").unwrap())
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("d3llm_test_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn mini_cfg(name: &str, recipe: Recipe, steps: usize) -> TrainCfg {
    TrainCfg {
        name: name.into(),
        model: "main".into(),
        recipe,
        curriculum: Curriculum::paper_default(),
        steps,
        lr: 2.5e-3,
        ent_weight: 0.0,
        corpus_size: 64,
        mixture: main_mixture(),
        seed: 77,
        init_from: None,
        teacher: None,
        log_every: 0,
    }
}

#[test]
fn diffusion_training_reduces_loss_and_checkpoints() {
    let Some(eng) = engine() else { return };
    let dir = tmp_dir("train");
    let cfg = mini_cfg("t-diff", Recipe::DiffusionPretrain, 30);
    let out = train(&eng, &cfg, &dir).unwrap();
    let first = out.log.first().unwrap().loss;
    let last = out.log.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");

    // checkpoint round-trip
    let loaded =
        ParamStore::load(TrainCfg::ckpt_path(&dir, "t-diff")).unwrap();
    assert_eq!(loaded.data.len(), out.params.data.len());
    assert_eq!(loaded.data, out.params.data);
    loaded.check(eng.manifest.model("main").unwrap()).unwrap();
}

#[test]
fn curriculum_schedules_progress_through_training() {
    let Some(eng) = engine() else { return };
    let dir = tmp_dir("curr");
    let mut cfg = mini_cfg("t-curr", Recipe::RandomMask, 20);
    cfg.curriculum = Curriculum::paper_default();
    let out = train(&eng, &cfg, &dir).unwrap();
    // t ramps 0 -> 0.8, k ramps 16 -> 32
    assert!(out.log.first().unwrap().t < 0.1);
    assert!(out.log.last().unwrap().t > 0.7);
    assert_eq!(out.log.first().unwrap().k, 16);
    assert_eq!(out.log.last().unwrap().k, 32);
}

#[test]
fn full_distillation_path_teacher_to_student() {
    let Some(eng) = engine() else { return };
    let dir = tmp_dir("distill");
    // teacher
    let teacher_cfg = mini_cfg("t-teacher", Recipe::DiffusionPretrain, 25);
    train(&eng, &teacher_cfg, &dir).unwrap();
    // student distilled on the teacher's pseudo-trajectories
    let mut student_cfg = mini_cfg("t-student", Recipe::PseudoTraj, 10);
    student_cfg.init_from = Some("t-teacher".into());
    student_cfg.teacher = Some("t-teacher".into());
    let out = train(&eng, &student_cfg, &dir).unwrap();
    assert!(out.log.last().unwrap().loss.is_finite());
    assert!(TrainCfg::ckpt_path(&dir, "t-student").exists());
}

#[test]
fn trajectory_extraction_caches_and_reloads() {
    let Some(eng) = engine() else { return };
    let c = eng.manifest.constants.clone();
    let tk = Tokenizer::new(c.vocab).unwrap();
    let spec = eng.manifest.model("main").unwrap().clone();
    let teacher = ParamStore::init(&spec, 9);
    let corpus = d3llm::data::train_corpus(
        &tk, &[(Family::Gsm8k, 1.0)], 12, 5);
    let cache_dir = tmp_dir("trajcache");

    let t0 = std::time::Instant::now();
    let first = trajectory::extract_all(&eng, &teacher.data, &corpus,
                                        &cache_dir, "test").unwrap();
    let cold = t0.elapsed();
    assert_eq!(first.len(), corpus.len());

    let t1 = std::time::Instant::now();
    let second = trajectory::extract_all(&eng, &teacher.data, &corpus,
                                         &cache_dir, "test").unwrap();
    let warm = t1.elapsed();
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a, b, "cache must return identical ranks");
    }
    assert!(warm < cold / 5, "cache hit must be much faster: {warm:?} vs {cold:?}");

    // rank sanity on one sample: gen region ranks are a permutation
    let p = corpus[0].prompt.len();
    let mut ranks: Vec<i32> =
        first[0][p..p + c.gen_train].to_vec();
    ranks.sort();
    assert_eq!(ranks, (0..c.gen_train as i32).collect::<Vec<_>>());
}

#[test]
fn ar_training_works_for_draft_model() {
    let Some(eng) = engine() else { return };
    let dir = tmp_dir("draft");
    let mut cfg = mini_cfg("t-draft", Recipe::ArLm, 25);
    cfg.model = "draft".into();
    let out = train(&eng, &cfg, &dir).unwrap();
    let first = out.log.first().unwrap().loss;
    let last = out.log.last().unwrap().loss;
    assert!(last < first, "draft loss {first} -> {last}");
}
