//! `cargo bench --bench interleave` — interleaved multi-session serving
//! vs. sequential batch=1 serving, fully deterministic (SimBackend, no
//! artifacts).
//!
//! Both schedules run the identical 8-request mixed-gen_len workload and
//! issue the *identical per-request forwards* (session trajectories are
//! schedule-independent — see tests/scheduler_determinism.rs). Costs are
//! charged on the repo's calibrated H100 cost model
//! (`metrics::GpuCostModel`): on 7-8B models every forward is
//! weight-bandwidth-bound, so the B concurrent same-shape forwards of one
//! interleaved round execute as one batched forward costing
//! `t * batch_factor(B, beta)` with beta = 0.2 (`DEFAULT_BATCH_BETA`)
//! instead of `t * B` serialized — that amortization is the aggregate
//! throughput win of keeping the engine busy across requests. Sequential
//! batch=1 serving decodes one request end-to-end at a time and can never
//! batch across requests (B = 1 always).
//!
//! The bench also reports the latency-shape effects (TTFT, per-request
//! completion) and the measured host-side scheduling overhead per step,
//! and asserts the >= 1.5x aggregate-throughput acceptance bar.

use std::collections::HashMap;
use std::time::Instant;

use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{DecodeCfg, DecodeSession, SessionPhase, SimBackend,
                    Strategy};
use d3llm::metrics::{batch_factor, GpuCostModel, DEFAULT_BATCH_BETA, H100};
use d3llm::util::stats::Summary;

const LENS: [usize; 8] = [128, 96, 64, 32, 128, 96, 64, 32];

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(10 + k % 4)).map(|i| 5 + ((i + 5 * k) % 80) as i32).collect()
}

fn cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    cfg
}

#[derive(Debug, Clone)]
struct Served {
    tokens: usize,
    completion: f64,
    ttft: f64,
    forwards: usize,
}

/// Sequential batch=1 serving: each request decodes end-to-end before the
/// next starts; every forward (prompt prefill included) is batch=1.
fn run_sequential(sim: &SimBackend, params: &[f32], m: &GpuCostModel)
                  -> (f64, Vec<Served>) {
    let mut clock = 0.0;
    let mut out = Vec::new();
    for (k, &gen_len) in LENS.iter().enumerate() {
        let mut s = DecodeSession::new(sim, cfg(), &prompt_for(k), gen_len)
            .expect("session");
        let mut ttft = None;
        loop {
            let prefill = s.phase() == SessionPhase::Prefill;
            let (f0, w0) =
                (s.res.mix.full_forwards, s.res.mix.window_forwards);
            let done = s.step(sim, params).expect("step");
            let (f1, w1) =
                (s.res.mix.full_forwards, s.res.mix.window_forwards);
            let fulls = (f1 - f0) + usize::from(prefill);
            clock += m.t_full * fulls as f64
                + m.t_window * (w1 - w0) as f64;
            if ttft.is_none() && s.progress().unmasked > 0 {
                ttft = Some(clock);
            }
            if done {
                break;
            }
        }
        let r = s.finish();
        out.push(Served {
            tokens: r.unmasked,
            completion: clock,
            ttft: ttft.unwrap_or(clock),
            forwards: r.forwards + 1, // + prompt prefill
        });
    }
    (clock, out)
}

/// Interleaved serving over `SessionPool`: all 8 requests live at once,
/// one round-robin step each per cycle; the round's same-kind forwards
/// are charged as one batched forward.
fn run_interleaved(sim: &SimBackend, params: &[f32], m: &GpuCostModel,
                   beta: f64) -> (f64, Vec<Served>, u64, f64) {
    let mut pool: SessionPool<usize> = SessionPool::new();
    for (k, &gen_len) in LENS.iter().enumerate() {
        let s = DecodeSession::new(sim, cfg(), &prompt_for(k), gen_len)
            .expect("session");
        pool.admit(format!("r{k}"), k, s);
    }
    let mut clock = 0.0;
    let mut prev: HashMap<String, d3llm::decode::SessionProgress> =
        pool.progress().into_iter().collect();
    let mut ttft: HashMap<String, f64> = HashMap::new();
    let mut served: Vec<Option<Served>> = (0..LENS.len()).map(|_| None)
        .collect();
    let wall = Instant::now();
    while !pool.is_empty() {
        let finished = pool.step_round(sim, params);
        let after: HashMap<String, d3llm::decode::SessionProgress> =
            pool.progress().into_iter().collect();
        let (mut b_full, mut b_win) = (0usize, 0usize);
        for (id, p) in &after {
            let q = &prev[id];
            if p.rounds == q.rounds {
                b_full += 1; // prompt prefill round
            } else {
                b_full += p.full_forwards - q.full_forwards;
                b_win += p.window_forwards - q.window_forwards;
            }
        }
        for f in &finished {
            let q = &prev[&f.id];
            let r = f.result.as_ref().expect("sim decode");
            b_full += r.mix.full_forwards - q.full_forwards;
            b_win += r.mix.window_forwards - q.window_forwards;
        }
        clock += m.t_full * batch_factor(b_full, beta)
            + m.t_window * batch_factor(b_win, beta);
        for (id, p) in &after {
            if p.unmasked > 0 {
                ttft.entry(id.clone()).or_insert(clock);
            }
        }
        for f in finished {
            let r = f.result.expect("sim decode");
            let t = *ttft.entry(f.id.clone()).or_insert(clock);
            served[f.tag] = Some(Served {
                tokens: r.unmasked,
                completion: clock,
                ttft: t,
                forwards: r.forwards + 1,
            });
        }
        prev = after;
    }
    let host = wall.elapsed().as_secs_f64();
    let steps = pool.steps_total;
    (clock, served.into_iter().map(|s| s.expect("all served")).collect(),
     steps, host)
}

fn report(name: &str, makespan: f64, served: &[Served]) -> f64 {
    let tokens: usize = served.iter().map(|s| s.tokens).sum();
    let lat: Vec<f64> = served.iter().map(|s| s.completion).collect();
    let ttft: Vec<f64> = served.iter().map(|s| s.ttft).collect();
    let (l, t) = (Summary::of(&lat), Summary::of(&ttft));
    let thr = tokens as f64 / makespan;
    println!(
        "{name:<14} makespan {makespan:7.2} s   agg {thr:7.1} tok/s   \
         lat p50/p95 {:.2}/{:.2} s   ttft p50/p95 {:.2}/{:.2} s",
        l.p50, l.p95, t.p50, t.p95
    );
    thr
}

fn main() {
    let sim = SimBackend::new(11);
    let params = vec![0.5f32; 8];
    let model = H100;
    let beta = DEFAULT_BATCH_BETA;

    println!(
        "== interleaved vs sequential serving: {} requests, gen_lens {:?} ==",
        LENS.len(),
        LENS
    );
    println!(
        "cost model {} (t_full {:.1} ms, t_window {:.1} ms), batch beta {beta}",
        model.name,
        model.t_full * 1e3,
        model.t_window * 1e3
    );

    let (seq_make, seq) = run_sequential(&sim, &params, &model);
    let (int_make, int, steps, host) =
        run_interleaved(&sim, &params, &model, beta);

    // identical per-request work: the schedule must not change any decode
    let seq_forwards: usize = seq.iter().map(|s| s.forwards).sum();
    let int_forwards: usize = int.iter().map(|s| s.forwards).sum();
    assert_eq!(seq_forwards, int_forwards,
               "schedules diverged: {seq_forwards} vs {int_forwards} forwards");
    let tokens: usize = seq.iter().map(|s| s.tokens).sum();
    assert_eq!(tokens, LENS.iter().sum::<usize>());

    let thr_seq = report("sequential", seq_make, &seq);
    let thr_int = report("interleaved", int_make, &int);
    let ratio = thr_int / thr_seq;
    println!(
        "\naggregate throughput: {ratio:.2}x  ({} forwards either way; \
         interleaving batches each round's {}-way forwards)",
        seq_forwards,
        LENS.len()
    );
    println!(
        "host scheduling overhead: {:.1} us/step over {} steps",
        host / steps.max(1) as f64 * 1e6,
        steps
    );
    assert!(
        ratio >= 1.5,
        "interleaving must deliver >= 1.5x aggregate throughput, got {ratio:.2}x"
    );
    d3llm::util::emit_bench_json("interleave", &format!(
        "{{\"bench\":\"interleave\",\"requests\":{},\
         \"seq_makespan_s\":{seq_make:.4},\
         \"interleaved_makespan_s\":{int_make:.4},\
         \"speedup\":{ratio:.3}}}",
        LENS.len()
    ));
    println!("PASS: >= 1.5x aggregate throughput for 8 concurrent requests");

    mixed_strategy_pool(&params);
}

/// Mixed-strategy pool: d3llm + ar + spec sessions interleave in one
/// `SessionPool`, same-shape rounds coalesce into B>1 batched backend
/// calls, and every per-request decode stays bit-identical to running
/// that session alone (B=1) on the same sim seed.
fn mixed_strategy_pool(params: &[f32]) {
    let seed = 17u64;
    let draft = vec![0.25f32; 8];
    let mk = |s: Strategy| {
        let mut c = DecodeCfg::preset(s);
        c.early_stop = false;
        c
    };
    let plan: [(Strategy, usize); 4] = [
        (Strategy::D3llm, 96),
        (Strategy::D3llm, 64),
        (Strategy::Ar, 32),
        (Strategy::Spec, 32),
    ];

    // B=1 references: each request alone on a fresh same-seed sim
    let mut refs = Vec::new();
    for (k, &(stg, gen_len)) in plan.iter().enumerate() {
        let ref_sim = SimBackend::new(seed);
        let mut s = DecodeSession::with_draft(&ref_sim, mk(stg),
                                              &prompt_for(k), gen_len,
                                              Some(&draft))
            .expect("session");
        while !s.step(&ref_sim, params).expect("step") {}
        refs.push(s.finish());
    }

    // the pooled run, with real batched rounds
    let sim = SimBackend::new(seed);
    let mut pool: SessionPool<usize> = SessionPool::new();
    for (k, &(stg, gen_len)) in plan.iter().enumerate() {
        let s = DecodeSession::with_draft(&sim, mk(stg), &prompt_for(k),
                                          gen_len, Some(&draft))
            .expect("session");
        pool.admit(format!("m{k}"), k, s);
    }
    let mut done: Vec<Option<d3llm::decode::GenResult>> =
        (0..plan.len()).map(|_| None).collect();
    while !pool.is_empty() {
        for f in pool.step_round(&sim, params) {
            done[f.tag] = Some(f.result.expect("mixed decode"));
        }
    }

    assert!(
        sim.window_batch_calls() > 0 && sim.max_window_batch() >= 2,
        "mixed pool must coalesce same-shape rounds into B>1 calls"
    );
    for (k, r) in done.iter().enumerate() {
        let r = r.as_ref().expect("all served");
        assert_eq!(r.tokens, refs[k].tokens,
                   "m{k}: batched pool diverged from B=1");
        assert_eq!(r.forwards, refs[k].forwards, "m{k}: forwards diverged");
    }
    println!(
        "PASS: mixed-strategy pool (d3llm+ar+spec) coalesced {} batched \
         window calls (max B={}) with per-request decodes bit-identical \
         to B=1",
        sim.window_batch_calls(),
        sim.max_window_batch()
    );
}
