//! `cargo bench --bench kv_pool` — paged KV-cache pool vs. dense
//! per-session allocation, fully deterministic (SimBackend, no
//! artifacts).
//!
//! Two acceptance bars, both asserted:
//!
//!   1. **Capacity**: at a fixed byte budget sized to hold exactly
//!      `DENSE_CAP` dense `[L, S_max, d_kv]` sessions, the paged pool
//!      admits >= 2x as many concurrent sessions — memory scales with
//!      live tokens (prompt + gen span) instead of `S_max`, and
//!      same-prefix sessions share their prompt pages.
//!   2. **Prefix sharing**: under a shared-system-prompt workload every
//!      session after the first adopts the registered prompt pages and
//!      skips its prompt-prefill forward entirely — measured as backend
//!      prefill-call reduction vs. the dense baseline.
//!
//! Two more paged-native bars ride along:
//!
//!   3. **Admission accounting**: with the shared prefix indexed, the
//!      fleet grows past the no-sharing worst-case bound
//!      (`max_pages / worst_case_pages`) — expected prefix adoption is
//!      credited at admission instead of charging every session its
//!      full span.
//!   4. **Staged bytes**: at 8 concurrent shared-prefix sessions, the
//!      engine-equivalent staging scratch (`model::KvStaging`, reused
//!      across rounds and sessions, copying only changed pages) moves
//!      >= 4x fewer bytes per windowed forward than the per-call dense
//!      `[L, S_max, d_kv]` gather it replaced.
//!
//! Throughout, every pooled session's decode output is asserted
//! bit-identical (tokens + forwards) to the dense-cache baseline, so the
//! capacity and prefill wins are free of behavior drift. The bench also
//! reports the incremental-refresh ratio (pages skipped vs. rewritten by
//! d3llm's periodic KV refresh) and emits a BENCH json record
//! (persisted by CI as a workflow artifact via `BENCH_JSON_DIR`).

use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{Backend, DecodeCfg, DecodeSession, GenResult,
                    SimBackend, Strategy};
use d3llm::model::kv_pool::{is_pool_exhausted, KvPoolCfg, SharedKvPool};
use d3llm::model::KvStaging;
use d3llm::util::emit_bench_json;

/// Dense sessions the shared budget is sized for.
const DENSE_CAP: usize = 4;
const GEN_LEN: usize = 64;
/// Concurrency of the staged-bytes phase (the acceptance bar's width).
const STAGE_SESSIONS: usize = 8;

/// Shared system prompt: two full 32-row pages, so the whole prefix is
/// adoptable and no partial-page CoW margin applies.
fn shared_prompt() -> Vec<i32> {
    (0..64).map(|i| 5 + (i * 7 % 80) as i32).collect()
}

fn cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    cfg
}

fn main() {
    let sim = SimBackend::new(41);
    let params = vec![0.5f32; 8];
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();

    let pool_cfg = {
        let base = KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block,
            budget_bytes: 0,
        };
        let budget = DENSE_CAP * base.dense_session_bytes();
        KvPoolCfg { budget_bytes: budget, ..base }
    };
    let dense_bytes = pool_cfg.dense_session_bytes();
    let budget_bytes = pool_cfg.budget_bytes;
    let page_bytes = pool_cfg.page_bytes();
    let kv = SharedKvPool::new(pool_cfg);

    println!("== paged KV pool vs dense per-session allocation ==");
    println!(
        "budget {budget_bytes} B = {DENSE_CAP} dense sessions of \
         {dense_bytes} B; {} pages of {} rows ({page_bytes} B each)",
        kv.max_pages(),
        c.block
    );

    // ---- dense baseline: one request end to end, counting its backend
    // prefill forwards (prompt prefill + periodic KV refreshes)
    let prompt = shared_prompt();
    let p0 = sim.prefill_calls();
    let dense_ref = {
        let mut s =
            DecodeSession::new(&sim, cfg(), &prompt, GEN_LEN).unwrap();
        while !s.step(&sim, &params).unwrap() {}
        s.finish()
    };
    let dense_prefills = sim.prefill_calls() - p0;
    println!(
        "dense baseline: {} tokens, {} forwards, {dense_prefills} backend \
         prefill calls per request",
        dense_ref.tokens.len(),
        dense_ref.forwards
    );

    // ---- capacity: admit same-workload sessions until the budget is
    // exhausted. The first session is stepped once so its prompt pages
    // register; the rest adopt them (continuous-serving admission order).
    let mut sched: SessionPool<usize> =
        SessionPool::new().with_kv_pool(kv.clone());
    let first = DecodeSession::with_pool(&sim, cfg(), &prompt, GEN_LEN,
                                         None, &kv)
        .expect("first session admits");
    sched.admit("s0".into(), 0, first);
    let fin = sched.step_round(&sim, &params); // prefill + registration
    assert!(fin.is_empty());

    let mut admitted = 1usize;
    loop {
        match DecodeSession::with_pool(&sim, cfg(), &prompt, GEN_LEN, None,
                                       &kv) {
            Ok(s) => {
                sched.admit(format!("s{admitted}"), admitted, s);
                admitted += 1;
            }
            Err(e) => {
                assert!(is_pool_exhausted(&e),
                        "admission must fail only on budget: {e:#}");
                break;
            }
        }
        assert!(admitted <= 256, "admission never saturated");
    }
    let usage = kv.usage();
    println!(
        "capacity at fixed budget: dense {DENSE_CAP} sessions vs paged \
         {admitted} sessions ({:.2}x; {} / {} pages committed)",
        admitted as f64 / DENSE_CAP as f64,
        usage.in_use + usage.reserved,
        usage.max_pages
    );
    assert!(
        admitted >= 2 * DENSE_CAP,
        "paged pool must hold >= 2x the dense session count at the same \
         budget ({admitted} vs {DENSE_CAP})"
    );

    // ---- admission accounting: expected shared-prefix adoption is
    // credited, so the fleet grows past the bound worst-case charging
    // would impose (every session billed its full no-sharing span)
    let worst = kv.worst_case_pages(prompt.len(), prompt.len() + GEN_LEN);
    let worst_bound = kv.max_pages() / worst;
    println!(
        "admission accounting: {admitted} sessions admitted vs {worst_bound} \
         under worst-case charging ({worst} pages/session)"
    );
    assert!(
        admitted > worst_bound,
        "prefix-aware admission must beat worst-case charging \
         ({admitted} <= {worst_bound})"
    );

    // ---- run the whole fleet to completion; every session must match
    // the dense baseline bit for bit
    let p1 = sim.prefill_calls();
    let mut done: Vec<Option<GenResult>> =
        (0..admitted).map(|_| None).collect();
    while !sched.is_empty() {
        for f in sched.step_round(&sim, &params) {
            done[f.tag] = Some(f.result.expect("pooled decode"));
        }
    }
    let pooled_prefills = sim.prefill_calls() - p1;
    for (i, r) in done.iter().enumerate() {
        let r = r.as_ref().expect("all served");
        assert_eq!(r.tokens, dense_ref.tokens,
                   "s{i}: paged decode diverged from the dense baseline");
        assert_eq!(r.forwards, dense_ref.forwards, "s{i}: forwards");
    }

    // ---- prefix sharing: every session after the first skipped its
    // prompt prefill (the fleet after the p1 snapshot holds the first
    // session's refreshes but not its already-spent prompt prefill)
    let stats = kv.stats();
    assert_eq!(stats.prefill_skips as usize, admitted - 1,
               "every warm session must skip its prompt prefill");
    let expected = admitted * dense_prefills - (admitted - 1) - 1;
    assert_eq!(pooled_prefills, expected,
               "prefill forwards: expected {expected}, got \
                {pooled_prefills}");
    let saved = admitted * dense_prefills - (pooled_prefills + 1);
    println!(
        "prefix sharing: {} prompt-prefill forwards skipped of {} total \
         dense-equivalent prefill calls ({:.1}% reduction, hit rate \
         {}/{} pages)",
        stats.prefill_skips,
        admitted * dense_prefills,
        100.0 * saved as f64 / (admitted * dense_prefills) as f64,
        stats.prefix_hits,
        stats.prefix_hits + stats.prefix_misses
    );
    assert!(saved >= admitted - 1);

    // ---- incremental refresh: d3llm's periodic KV refresh must have
    // skipped current pages (prompt + settled blocks) instead of
    // rewriting every row
    assert!(stats.pages_refreshed > 0, "refresh rounds install pages");
    assert!(
        stats.refresh_skips > 0,
        "incremental refresh must skip current pages"
    );
    println!(
        "incremental refresh: {} pages rewritten, {} skipped \
         ({:.1}% of page-installs avoided); cow copies {}, evictions {}",
        stats.pages_refreshed,
        stats.refresh_skips,
        100.0 * stats.refresh_skips as f64
            / (stats.pages_refreshed + stats.refresh_skips) as f64,
        stats.cow_copies,
        stats.evictions
    );

    // ---- staged KV bytes: the paged-native hot path vs the dense
    // gather it replaced, at the acceptance bar's width
    let (staged_bytes, gather_bytes, staged_forwards) =
        staged_bytes_phase(&sim, &params);
    let reduction = gather_bytes as f64 / staged_bytes.max(1) as f64;
    println!(
        "staged KV bytes @ {STAGE_SESSIONS} shared-prefix sessions: \
         {staged_bytes} B staged vs {gather_bytes} B dense-gathered over \
         {staged_forwards} windowed forwards ({reduction:.2}x reduction)"
    );
    assert!(
        reduction >= 4.0,
        "paged-native staging must move >= 4x fewer bytes than the dense \
         gather per decode round, got {reduction:.2}x"
    );

    emit_bench_json("kv_pool", &format!(
        "{{\"bench\":\"kv_pool\",\"dense_cap\":{DENSE_CAP},\
         \"paged_sessions\":{admitted},\"capacity_x\":{:.3},\
         \"worst_case_bound\":{worst_bound},\"prefill_skips\":{},\
         \"stage_sessions\":{STAGE_SESSIONS},\
         \"staged_bytes\":{staged_bytes},\
         \"dense_gather_bytes\":{gather_bytes},\
         \"staging_reduction_x\":{reduction:.3}}}",
        admitted as f64 / DENSE_CAP as f64,
        stats.prefill_skips,
    ));
    println!(
        "PASS: >= 2x session capacity at fixed budget ({admitted} vs \
         {DENSE_CAP}), admission past the worst-case bound, >= 4x staged-\
         byte reduction, measured prefill reduction, bit-identical decode"
    );
}

/// Drive `STAGE_SESSIONS` shared-prefix sessions round-robin over a fresh
/// pool, staging each session's page-table view once per windowed forward
/// through one engine-equivalent [`KvStaging`] scratch — exactly what
/// `Engine::decode_window` does per call — and totalling the bytes the
/// replaced per-call dense gather would have moved instead. Returns
/// (staged bytes, dense-gather bytes, windowed forwards staged).
fn staged_bytes_phase(sim: &SimBackend, params: &[f32])
                      -> (u64, u64, u64) {
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let base = KvPoolCfg {
        layers: spec.n_layers,
        d_kv: spec.d_kv,
        s_max: c.s_max,
        page_rows: c.block,
        budget_bytes: 0,
    };
    let kv = SharedKvPool::new(KvPoolCfg {
        budget_bytes: STAGE_SESSIONS * base.dense_session_bytes(),
        ..base
    });
    let prompt = shared_prompt();

    // first session steps once so its prompt pages register; the other
    // seven adopt them (continuous-serving admission order)
    let mut sessions: Vec<DecodeSession> = Vec::new();
    let mut first =
        DecodeSession::with_pool(sim, cfg(), &prompt, GEN_LEN, None, &kv)
            .expect("first staging session admits");
    let done = first.step(sim, params).expect("prefill");
    assert!(!done);
    sessions.push(first);
    for _ in 1..STAGE_SESSIONS {
        sessions.push(
            DecodeSession::with_pool(sim, cfg(), &prompt, GEN_LEN, None,
                                     &kv)
                .expect("staging session admits"),
        );
    }

    let mut stage = KvStaging::new();
    let mut gather_bytes = 0u64;
    let mut staged_forwards = 0u64;
    let mut live = vec![true; sessions.len()];
    while live.iter().any(|&l| l) {
        for (i, session) in sessions.iter_mut().enumerate() {
            if !live[i] {
                continue;
            }
            let before = session.progress().window_forwards;
            let done = session.step(sim, params).expect("staged decode");
            let wins = session.progress().window_forwards - before;
            for _ in 0..wins {
                stage.stage(session.cache.as_ref()).expect("staging");
                gather_bytes += stage.dense_gather_bytes();
                staged_forwards += 1;
            }
            if done {
                live[i] = false;
            }
        }
    }
    (stage.stats().bytes_copied, gather_bytes, staged_forwards)
}
