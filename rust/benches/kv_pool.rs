//! `cargo bench --bench kv_pool` — paged KV-cache pool vs. dense
//! per-session allocation, fully deterministic (SimBackend, no
//! artifacts).
//!
//! Two acceptance bars, both asserted:
//!
//!   1. **Capacity**: at a fixed byte budget sized to hold exactly
//!      `DENSE_CAP` dense `[L, S_max, d_kv]` sessions, the paged pool
//!      admits >= 2x as many concurrent sessions — memory scales with
//!      live tokens (prompt + gen span) instead of `S_max`, and
//!      same-prefix sessions share their prompt pages.
//!   2. **Prefix sharing**: under a shared-system-prompt workload every
//!      session after the first adopts the registered prompt pages and
//!      skips its prompt-prefill forward entirely — measured as backend
//!      prefill-call reduction vs. the dense baseline.
//!
//! Throughout, every pooled session's decode output is asserted
//! bit-identical (tokens + forwards) to the dense-cache baseline, so the
//! capacity and prefill wins are free of behavior drift. The bench also
//! reports the incremental-refresh ratio (pages skipped vs. rewritten by
//! d3llm's periodic KV refresh).

use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{Backend, DecodeCfg, DecodeSession, GenResult,
                    SimBackend, Strategy};
use d3llm::model::kv_pool::{is_pool_exhausted, KvPoolCfg, SharedKvPool};

/// Dense sessions the shared budget is sized for.
const DENSE_CAP: usize = 4;
const GEN_LEN: usize = 64;

/// Shared system prompt: two full 32-row pages, so the whole prefix is
/// adoptable and no partial-page CoW margin applies.
fn shared_prompt() -> Vec<i32> {
    (0..64).map(|i| 5 + (i * 7 % 80) as i32).collect()
}

fn cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;
    cfg
}

fn main() {
    let sim = SimBackend::new(41);
    let params = vec![0.5f32; 8];
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();

    let pool_cfg = {
        let base = KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block,
            budget_bytes: 0,
        };
        let budget = DENSE_CAP * base.dense_session_bytes();
        KvPoolCfg { budget_bytes: budget, ..base }
    };
    let dense_bytes = pool_cfg.dense_session_bytes();
    let budget_bytes = pool_cfg.budget_bytes;
    let page_bytes = pool_cfg.page_bytes();
    let kv = SharedKvPool::new(pool_cfg);

    println!("== paged KV pool vs dense per-session allocation ==");
    println!(
        "budget {budget_bytes} B = {DENSE_CAP} dense sessions of \
         {dense_bytes} B; {} pages of {} rows ({page_bytes} B each)",
        kv.max_pages(),
        c.block
    );

    // ---- dense baseline: one request end to end, counting its backend
    // prefill forwards (prompt prefill + periodic KV refreshes)
    let prompt = shared_prompt();
    let p0 = sim.prefill_calls();
    let dense_ref = {
        let mut s =
            DecodeSession::new(&sim, cfg(), &prompt, GEN_LEN).unwrap();
        while !s.step(&sim, &params).unwrap() {}
        s.finish()
    };
    let dense_prefills = sim.prefill_calls() - p0;
    println!(
        "dense baseline: {} tokens, {} forwards, {dense_prefills} backend \
         prefill calls per request",
        dense_ref.tokens.len(),
        dense_ref.forwards
    );

    // ---- capacity: admit same-workload sessions until the budget is
    // exhausted. The first session is stepped once so its prompt pages
    // register; the rest adopt them (continuous-serving admission order).
    let mut sched: SessionPool<usize> =
        SessionPool::new().with_kv_pool(kv.clone());
    let first = DecodeSession::with_pool(&sim, cfg(), &prompt, GEN_LEN,
                                         None, &kv)
        .expect("first session admits");
    sched.admit("s0".into(), 0, first);
    let fin = sched.step_round(&sim, &params); // prefill + registration
    assert!(fin.is_empty());

    let mut admitted = 1usize;
    loop {
        match DecodeSession::with_pool(&sim, cfg(), &prompt, GEN_LEN, None,
                                       &kv) {
            Ok(s) => {
                sched.admit(format!("s{admitted}"), admitted, s);
                admitted += 1;
            }
            Err(e) => {
                assert!(is_pool_exhausted(&e),
                        "admission must fail only on budget: {e:#}");
                break;
            }
        }
        assert!(admitted <= 256, "admission never saturated");
    }
    let usage = kv.usage();
    println!(
        "capacity at fixed budget: dense {DENSE_CAP} sessions vs paged \
         {admitted} sessions ({:.2}x; {} / {} pages committed)",
        admitted as f64 / DENSE_CAP as f64,
        usage.in_use + usage.reserved,
        usage.max_pages
    );
    assert!(
        admitted >= 2 * DENSE_CAP,
        "paged pool must hold >= 2x the dense session count at the same \
         budget ({admitted} vs {DENSE_CAP})"
    );

    // ---- run the whole fleet to completion; every session must match
    // the dense baseline bit for bit
    let p1 = sim.prefill_calls();
    let mut done: Vec<Option<GenResult>> =
        (0..admitted).map(|_| None).collect();
    while !sched.is_empty() {
        for f in sched.step_round(&sim, &params) {
            done[f.tag] = Some(f.result.expect("pooled decode"));
        }
    }
    let pooled_prefills = sim.prefill_calls() - p1;
    for (i, r) in done.iter().enumerate() {
        let r = r.as_ref().expect("all served");
        assert_eq!(r.tokens, dense_ref.tokens,
                   "s{i}: paged decode diverged from the dense baseline");
        assert_eq!(r.forwards, dense_ref.forwards, "s{i}: forwards");
    }

    // ---- prefix sharing: every session after the first skipped its
    // prompt prefill (the fleet after the p1 snapshot holds the first
    // session's refreshes but not its already-spent prompt prefill)
    let stats = kv.stats();
    assert_eq!(stats.prefill_skips as usize, admitted - 1,
               "every warm session must skip its prompt prefill");
    let expected = admitted * dense_prefills - (admitted - 1) - 1;
    assert_eq!(pooled_prefills, expected,
               "prefill forwards: expected {expected}, got \
                {pooled_prefills}");
    let saved = admitted * dense_prefills - (pooled_prefills + 1);
    println!(
        "prefix sharing: {} prompt-prefill forwards skipped of {} total \
         dense-equivalent prefill calls ({:.1}% reduction, hit rate \
         {}/{} pages)",
        stats.prefill_skips,
        admitted * dense_prefills,
        100.0 * saved as f64 / (admitted * dense_prefills) as f64,
        stats.prefix_hits,
        stats.prefix_hits + stats.prefix_misses
    );
    assert!(saved >= admitted - 1);

    // ---- incremental refresh: d3llm's periodic KV refresh must have
    // skipped current pages (prompt + settled blocks) instead of
    // rewriting every row
    assert!(stats.pages_refreshed > 0, "refresh rounds install pages");
    assert!(
        stats.refresh_skips > 0,
        "incremental refresh must skip current pages"
    );
    println!(
        "incremental refresh: {} pages rewritten, {} skipped \
         ({:.1}% of page-installs avoided); cow copies {}, evictions {}",
        stats.pages_refreshed,
        stats.refresh_skips,
        100.0 * stats.refresh_skips as f64
            / (stats.pages_refreshed + stats.refresh_skips) as f64,
        stats.cow_copies,
        stats.evictions
    );

    println!(
        "PASS: >= 2x session capacity at fixed budget ({admitted} vs \
         {DENSE_CAP}) with measured prefill reduction and bit-identical \
         decode output"
    );
}
