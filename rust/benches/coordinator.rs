//! `cargo bench --bench coordinator` — L3 overhead microbenchmarks that
//! need no model: batcher throughput, JSON protocol round-trip, AUP
//! computation, KV-cache row commits, tokenizer encode/decode.
//!
//! These are the pure-coordinator costs that must stay negligible next to
//! a ~6 ms model forward (see EXPERIMENTS.md §Perf).

use d3llm::coordinator::batcher::Batcher;
use d3llm::coordinator::protocol;
use d3llm::metrics::aup::{aup_from_points, Point};
use d3llm::model::KvCache;
use d3llm::tokenizer::Tokenizer;
use d3llm::util::stats::{bench, bench_line};

fn main() {
    // ---- batcher: 1k push+pop with mixed priorities
    let secs = bench(3, 50, || {
        let mut b: Batcher<u64> = Batcher::new(2048);
        for i in 0..1000u64 {
            b.push(i, (i % 7) as i64);
        }
        while b.pop().is_some() {}
    });
    println!("{}", bench_line("batcher 1k push+pop", &secs));

    // ---- protocol: parse + serialize one request/response
    let req =
        r#"{"id":"r1","prompt":"Q EVAL 3 + 4 * 2","gen_len":96,"priority":1}"#;
    let secs = bench(10, 200, || {
        let _ = protocol::parse_request(req).unwrap();
    });
    println!("{}", bench_line("protocol parse_request", &secs));

    let resp = protocol::GenResponse {
        id: "r1".into(),
        text: "STEP 4 * 2 = 8 ; ANS 11".into(),
        tokens: (0..64).collect(),
        tpf: 5.2,
        forwards: 12,
        gen_tokens: 61,
        queue_ms: 0.2,
        decode_ms: 80.0,
        slo: "standard".into(),
        deadline_missed: false,
    };
    let secs = bench(10, 200, || {
        let _ = protocol::ok_response(&resp);
    });
    println!("{}", bench_line("protocol ok_response (64 tok)", &secs));

    // ---- AUP over a realistic sweep
    let pts: Vec<Point> = (0..24)
        .map(|i| Point { rho: 1.0 + i as f64 * 0.4,
                         acc: 75.0 - i as f64 * 0.2 })
        .collect();
    let secs = bench(10, 500, || {
        let _ = aup_from_points(&pts, 3.0, None);
    });
    println!("{}", bench_line("aup 24-point sweep", &secs));

    // ---- KV cache: commit one completed block (32 rows x 3 layers)
    let mut cache = KvCache::new(3, 384, 96);
    let k_win = vec![0.5f32; 3 * 96 * 96];
    let pairs: Vec<(usize, usize)> = (0..32).map(|i| (i, 100 + i)).collect();
    let secs = bench(5, 200, || {
        cache.commit_window_rows(&k_win, &k_win, 96, &pairs);
    });
    println!("{}", bench_line("kv commit 32-row block", &secs));

    // ---- tokenizer
    let tk = Tokenizer::new(128).unwrap();
    let text = "STEP 1 2 + 7 = 1 9 ; STEP 1 9 * 2 = 3 8 ; ANS 3 8";
    let ids = tk.encode(text).unwrap();
    let secs = bench(10, 500, || {
        let _ = tk.encode(text).unwrap();
    });
    println!("{}", bench_line("tokenizer encode (25 tok)", &secs));
    let secs = bench(10, 500, || {
        let _ = tk.decode(&ids);
    });
    println!("{}", bench_line("tokenizer decode (25 tok)", &secs));
}
