//! `cargo bench --bench router` — the multi-worker fleet router under a
//! deterministic 8-prefix-family workload (SimBackend + virtual clock,
//! no artifacts, no wall-time dependence).
//!
//! The bench drives the real placement core (`RouterCore`: rendezvous
//! hashing over the prefix-chain routing key, backlog-aware spill,
//! least-loaded cold placement) against per-replica serving stacks built
//! from the real `Batcher` + `SessionPool` + `SharedKvPool`, ticking all
//! replicas in lockstep on a virtual millisecond clock (`ROUND_MS` per
//! pool round — rounds are batched, so a round costs the same whatever
//! its width). Request cost is calibrated from one solo session first.
//!
//! Two phases:
//!   1. *Affinity*: open-loop arrivals at ~60% fleet utilization with
//!      roomy queues. Every request is keyed (same 64-token prompt per
//!      family, two full 32-row pages), so placement should pin each
//!      family to its rendezvous home — vs ~1/N co-location under random
//!      placement. Co-location is what makes prefix pages adoptable, so
//!      the phase also checks the pools actually skipped prompt prefills.
//!   2. *Throughput*: closed loop (all requests pending at t=0, tight
//!      queues, dispatch gated on queue room like a blocking client) at
//!      1 replica vs `FLEET` replicas. Backlog-aware spill keeps the
//!      fleet work-conserving even when rendezvous hashing concentrates
//!      families, so aggregate throughput must scale.
//!
//! Acceptance (asserted):
//!   * phase 1 affinity-hit rate >= 80% (random placement: ~1/N = 25%);
//!   * phase 1 fleet prefill skips > 0 (co-location paid off in pages);
//!   * phase 2 aggregate throughput at 4 replicas >= 2x the 1-replica
//!     baseline on the same workload;
//!   * nothing is lost: every request decodes in every run.
//!
//! Emits `BENCH_router.json` with the hit rate, per-replica spread,
//! spill/cold counters, adoption stats, and both throughput figures.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;

use d3llm::coordinator::batcher::{Admission, Batcher};
use d3llm::coordinator::router::RouterCore;
use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{self, DecodeCfg, DecodeSession, SimBackend, Strategy};
use d3llm::model::kv_pool::{prefix_routing_key, KvPoolCfg, SharedKvPool};
use d3llm::util::json::Json;

/// Virtual duration of one pool round (ms).
const ROUND_MS: f64 = 5.0;
const GEN_LEN: usize = 32;
/// Live sessions per replica pool.
const MAX_LIVE: usize = 4;
const N_FAMILIES: usize = 8;
const PER_FAMILY: usize = 12;
const N_REQUESTS: usize = N_FAMILIES * PER_FAMILY;
const FLEET: usize = 4;
/// Phase-1 queue bound: roomier than the whole run, so placement is pure
/// affinity. Phase-2 queue bound: tight, so backlog spill has to work.
const OPEN_QUEUE: usize = 128;
const TIGHT_QUEUE: usize = 4;

fn cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false; // sim argmax never emits EOS by default
    cfg
}

/// One 64-token prompt per family (two full 32-row pages): every member
/// shares the full prompt, so the routing key is the family identity and
/// co-located members can adopt each other's prompt pages wholesale.
fn family_prompt(family: usize) -> Vec<i32> {
    (0..64).map(|i| 5 + ((i * 7 + family * 13) % 80) as i32).collect()
}

struct Replica {
    batcher: Batcher<usize>,
    pool: SessionPool<usize>,
    kv: SharedKvPool,
    served: usize,
}

struct RunOut {
    makespan_ms: f64,
    affinity_hits: u64,
    affinity_spills: u64,
    cold: u64,
    prefill_skips: u64,
    prefix_hits: u64,
    served_per_replica: Vec<usize>,
}

/// Drive `n_replicas` serving stacks behind one `RouterCore` until every
/// request has decoded. `inter_arrival_ms = 0` is the closed loop (all
/// requests pending at t=0); dispatch is gated on queue room, so a full
/// fleet backpressures the client instead of shedding.
fn run_fleet(seed: u64, n_replicas: usize, max_queue: usize,
             inter_arrival_ms: f64) -> RunOut {
    let sim = SimBackend::new(seed);
    let params = vec![0.5f32; 8];
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let core = RouterCore::new(n_replicas, max_queue);
    let mut reps: Vec<Replica> = (0..n_replicas)
        .map(|_| {
            let kv = SharedKvPool::new(KvPoolCfg {
                layers: spec.n_layers,
                d_kv: spec.d_kv,
                s_max: c.s_max,
                page_rows: c.block,
                budget_bytes: 1 << 20,
            });
            Replica {
                batcher: Batcher::new(max_queue),
                pool: SessionPool::new().with_kv_pool(kv.clone()),
                kv,
                served: 0,
            }
        })
        .collect();
    // the same chain hash the replica pools index pages by — computed
    // once per family, exactly like the acceptor's RouteKeyCtx
    let keys: Vec<u64> = (0..N_FAMILIES)
        .map(|f| {
            let p = family_prompt(f);
            let geo = decode::kv_admission_geometry(&cfg(), &c, p.len(), 0);
            prefix_routing_key(&geo.prefix_tag, spec.n_layers, spec.d_kv,
                               c.block, &p, geo.prefix_rows)
                .expect("a 64-token prompt spans full pages")
        })
        .collect();
    let arrival = |i: usize| i as f64 * inter_arrival_ms;

    let mut pending: VecDeque<usize> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut now_ms = 0.0f64;
    let mut done = 0usize;
    while done < N_REQUESTS {
        while next_arrival < N_REQUESTS && arrival(next_arrival) <= now_ms {
            pending.push_back(next_arrival);
            next_arrival += 1;
        }
        // dispatch while someone has queue room; placement sees live
        // gauges, so a backlogged home spills to a fitting sibling
        while let Some(&i) = pending.front() {
            if reps.iter().all(|rep| rep.batcher.len() >= max_queue) {
                break; // whole fleet backlogged: the client waits
            }
            for (r, rep) in reps.iter().enumerate() {
                let g = core.gauge(r);
                g.queue_depth
                    .store(rep.batcher.len() as u64, Ordering::Relaxed);
                g.active_sessions
                    .store(rep.pool.len() as u64, Ordering::Relaxed);
                g.est_wait_ms.store(
                    rep.batcher.estimated_wait_ms().ceil() as u64,
                    Ordering::Relaxed,
                );
            }
            let r = core
                .place(Some(keys[i % N_FAMILIES]), None)
                .expect("live fleet")
                .replica();
            match reps[r].batcher.admit(i, 0, None, now_ms as u64) {
                Admission::Admitted(None) => {}
                _ => unreachable!("placement is gated on queue room"),
            }
            pending.pop_front();
        }
        // one lockstep round across the fleet (replicas run in parallel)
        let mut any_live = false;
        for rep in reps.iter_mut() {
            while rep.pool.len() < MAX_LIVE {
                let i = match rep.batcher.pop() {
                    Some(q) => q.payload,
                    None => break,
                };
                let s = DecodeSession::with_pool(
                    &sim, cfg(), &family_prompt(i % N_FAMILIES), GEN_LEN,
                    None, &rep.kv)
                    .unwrap();
                rep.pool.admit(format!("r{i}"), i, s);
            }
            if rep.pool.is_empty() {
                continue;
            }
            any_live = true;
            rep.pool.set_now_ms(now_ms as u64);
            let finished = rep.pool.step_round(&sim, &params);
            rep.batcher.observe_round_ms(ROUND_MS);
            for f in finished {
                f.result.expect("sim decode");
                rep.served += 1;
                done += 1;
            }
        }
        if !any_live {
            // idle gap before the next arrival: jump the clock (always
            // advancing, so a bookkeeping bug can't spin forever)
            now_ms += ROUND_MS;
            if next_arrival < N_REQUESTS {
                now_ms = now_ms.max(arrival(next_arrival));
            }
            continue;
        }
        now_ms += ROUND_MS;
    }
    RunOut {
        makespan_ms: now_ms,
        affinity_hits: core.affinity_hits.load(Ordering::Relaxed),
        affinity_spills: core.affinity_spills.load(Ordering::Relaxed),
        cold: core.cold_placements.load(Ordering::Relaxed),
        prefill_skips: reps.iter().map(|r| r.kv.stats().prefill_skips).sum(),
        prefix_hits: reps.iter().map(|r| r.kv.stats().prefix_hits).sum(),
        served_per_replica: reps.iter().map(|r| r.served).collect(),
    }
}

fn main() {
    // ---- calibrate: rounds one request needs, solo
    let sim = SimBackend::new(7);
    let params = vec![0.5f32; 8];
    let mut solo =
        DecodeSession::new(&sim, cfg(), &family_prompt(0), GEN_LEN).unwrap();
    let mut solo_rounds = 1u64; // the finishing step counts too
    while !solo.step(&sim, &params).unwrap() {
        solo_rounds += 1;
    }
    let service_ms = solo_rounds as f64 * ROUND_MS;
    println!(
        "== fleet router: {N_REQUESTS} requests, {N_FAMILIES} prefix \
         families, {FLEET} replicas ==\n\
         request cost {solo_rounds} rounds x {ROUND_MS} ms = {service_ms} ms"
    );

    // ---- phase 1: prefix affinity at ~60% fleet utilization
    let inter_arrival_ms = service_ms / (MAX_LIVE * FLEET) as f64 / 0.6;
    let aff = run_fleet(7, FLEET, OPEN_QUEUE, inter_arrival_ms);
    let placed = aff.affinity_hits + aff.affinity_spills + aff.cold;
    assert_eq!(placed as usize, N_REQUESTS, "placements went missing");
    let hit_rate = aff.affinity_hits as f64 / placed as f64;
    let random_rate = 1.0 / FLEET as f64;
    println!(
        "affinity: {}/{placed} keyed requests landed on their prefix home \
         ({:.0}% vs ~{:.0}% random), spread {:?}, prefill skips {} \
         (prefix pages adopted {})",
        aff.affinity_hits, hit_rate * 100.0, random_rate * 100.0,
        aff.served_per_replica, aff.prefill_skips, aff.prefix_hits
    );
    assert!(
        hit_rate >= 0.80,
        "affinity-hit rate {:.2} below 0.80 (random would be ~{random_rate:.2})",
        hit_rate
    );
    assert!(aff.prefill_skips > 0,
            "co-located family members never adopted prompt pages");

    // ---- phase 2: aggregate throughput, 1 replica vs the fleet
    let solo_run = run_fleet(7, 1, TIGHT_QUEUE, 0.0);
    let fleet_run = run_fleet(7, FLEET, TIGHT_QUEUE, 0.0);
    let tp1 = N_REQUESTS as f64 / (solo_run.makespan_ms / 1000.0);
    let tp4 = N_REQUESTS as f64 / (fleet_run.makespan_ms / 1000.0);
    let speedup = tp4 / tp1;
    println!(
        "throughput: 1 replica {:.1} req/s ({:.0} ms), {FLEET} replicas \
         {:.1} req/s ({:.0} ms) -> {speedup:.2}x (spills {}, spread {:?})",
        tp1, solo_run.makespan_ms, tp4, fleet_run.makespan_ms,
        fleet_run.affinity_spills, fleet_run.served_per_replica
    );
    assert!(
        speedup >= 2.0,
        "{FLEET} replicas reached only {speedup:.2}x the 1-replica \
         throughput"
    );

    // ---- report + BENCH json
    let spread =
        aff.served_per_replica.iter().map(|&s| Json::num(s as f64));
    let j = Json::obj(vec![
        ("bench", Json::str("router")),
        ("requests", Json::num(N_REQUESTS as f64)),
        ("families", Json::num(N_FAMILIES as f64)),
        ("workers", Json::num(FLEET as f64)),
        ("round_ms", Json::num(ROUND_MS)),
        ("service_ms", Json::num(service_ms)),
        ("affinity_hit_rate", Json::num(hit_rate)),
        ("random_hit_rate", Json::num(random_rate)),
        ("affinity_spills", Json::num(aff.affinity_spills as f64)),
        ("cold_placements", Json::num(aff.cold as f64)),
        ("prefill_skips", Json::num(aff.prefill_skips as f64)),
        ("prefix_page_hits", Json::num(aff.prefix_hits as f64)),
        ("served_per_replica", Json::arr(spread)),
        ("throughput_1_replica_rps", Json::num(tp1)),
        ("throughput_fleet_rps", Json::num(tp4)),
        ("fleet_speedup_x", Json::num(speedup)),
        ("fleet_spills", Json::num(fleet_run.affinity_spills as f64)),
        ("makespan_1_replica_ms", Json::num(solo_run.makespan_ms)),
        ("makespan_fleet_ms", Json::num(fleet_run.makespan_ms)),
    ]);
    d3llm::util::emit_bench_json("router", &j.to_string());
    println!(
        "PASS: {:.0}% prefix-affinity (random ~{:.0}%) and {speedup:.2}x \
         aggregate throughput at {FLEET} replicas",
        hit_rate * 100.0, random_rate * 100.0
    );
}
