//! `cargo bench --bench distill` — pooled (interleaved) teacher
//! pseudo-trajectory extraction vs. the sequential width-1 baseline,
//! fully deterministic (SimBackend, no artifacts).
//!
//! Both schedules run the identical corpus and issue the *identical
//! per-sample forwards* (the teacher scan is schedule-independent, see
//! tests/props.rs). Costs are charged on the repo's calibrated H100 cost
//! model: the B same-shape forwards of one interleaved round execute as
//! one batched forward costing `t * batch_factor(B, beta)` instead of
//! `t * B` serialized. The bench asserts the >= 1.5x modeled-throughput
//! acceptance bar at 8 concurrent extraction sessions and emits a BENCH
//! json line for CI trend tracking.
//!
//! A second phase re-runs extraction over a corpus whose prompts repeat,
//! bound to a `SharedKvPool`: the repeated prompts adopt the first
//! cohort's teacher pages, skip their prompt-prefill forwards entirely,
//! and still produce bit-identical ranks.

use std::collections::HashMap;

use d3llm::coordinator::scheduler::SessionPool;
use d3llm::data::{train_corpus, Family, Sample};
use d3llm::decode::{Backend, SessionPhase, SessionProgress, SimBackend};
use d3llm::metrics::{batch_factor, GpuCostModel, DEFAULT_BATCH_BETA, H100};
use d3llm::model::{KvPoolCfg, SharedKvPool};
use d3llm::tokenizer::Tokenizer;
use d3llm::trajectory::{teacher_session, EXTRACT_VARIANT};

const N: usize = 16;
const WIDTH: usize = 8;

fn corpus(sim: &SimBackend, n: usize) -> Vec<Sample> {
    let tk = Tokenizer::new(sim.constants().vocab).unwrap();
    train_corpus(&tk, &[(Family::Gsm8k, 0.5), (Family::Math, 0.5)], n, 3)
}

/// Sequential width-1 baseline: each teacher scan runs end-to-end before
/// the next starts; every forward (prompt prefill included) is batch=1.
fn run_sequential(sim: &SimBackend, corpus: &[Sample], teacher: &[f32],
                  m: &GpuCostModel) -> (f64, Vec<Vec<i32>>, usize) {
    let mut clock = 0.0;
    let mut ranks = Vec::new();
    let mut forwards = 0usize;
    for s in corpus {
        let mut sess =
            teacher_session(sim, s, EXTRACT_VARIANT, None).expect("session");
        loop {
            let prefill = sess.phase() == SessionPhase::Prefill;
            let (f0, w0) =
                (sess.res.mix.full_forwards, sess.res.mix.window_forwards);
            let done = sess.step(sim, teacher).expect("step");
            let fulls = (sess.res.mix.full_forwards - f0)
                + usize::from(prefill);
            let wins = sess.res.mix.window_forwards - w0;
            clock += m.t_full * fulls as f64 + m.t_window * wins as f64;
            if done {
                break;
            }
        }
        let r = sess.finish();
        forwards += r.forwards + 1; // + prompt prefill
        ranks.push(r.unmask_ranks.expect("trajectory ranks"));
    }
    (clock, ranks, forwards)
}

/// Interleaved extraction: up to `width` teacher scans in flight, one
/// round each per cycle; each round's same-shape forwards are charged as
/// one batched forward. With `kv`, sessions bind to the shared page pool.
fn run_interleaved(sim: &SimBackend, corpus: &[Sample], teacher: &[f32],
                   m: &GpuCostModel, beta: f64, width: usize,
                   kv: Option<&SharedKvPool>)
                   -> (f64, Vec<Vec<i32>>, usize) {
    let mut pool: SessionPool<usize> = SessionPool::new();
    let mut prev: HashMap<String, SessionProgress> = HashMap::new();
    let mut ranks: Vec<Option<Vec<i32>>> =
        (0..corpus.len()).map(|_| None).collect();
    let mut forwards = 0usize;
    let mut clock = 0.0;
    let mut next = 0usize;
    while next < corpus.len() || !pool.is_empty() {
        while pool.len() < width && next < corpus.len() {
            let s = teacher_session(sim, &corpus[next], EXTRACT_VARIANT, kv)
                .expect("admit");
            let id = format!("t{next}");
            prev.insert(id.clone(), s.progress());
            pool.admit(id, next, s);
            next += 1;
        }
        let finished = pool.step_round(sim, teacher);
        let after: HashMap<String, SessionProgress> =
            pool.progress().into_iter().collect();
        let (mut b_full, mut b_win) = (0usize, 0usize);
        for (id, p) in &after {
            let q = &prev[id];
            if p.rounds == q.rounds {
                b_full += 1; // prompt-prefill round
            } else {
                b_full += p.full_forwards - q.full_forwards;
                b_win += p.window_forwards - q.window_forwards;
            }
        }
        for f in &finished {
            let q = &prev[&f.id];
            let r = f.result.as_ref().expect("sim extraction");
            b_full += r.mix.full_forwards - q.full_forwards;
            b_win += r.mix.window_forwards - q.window_forwards;
        }
        clock += m.t_full * batch_factor(b_full, beta)
            + m.t_window * batch_factor(b_win, beta);
        for f in finished {
            let r = f.result.expect("sim extraction");
            forwards += r.forwards + 1; // + prompt prefill (or its skip)
            ranks[f.tag] = Some(r.unmask_ranks.expect("trajectory ranks"));
        }
        prev = after;
    }
    (clock, ranks.into_iter().map(|r| r.expect("all extracted")).collect(),
     forwards)
}

fn main() {
    let m = H100;
    let beta = DEFAULT_BATCH_BETA;

    println!(
        "== pooled vs sequential teacher trajectory extraction: {N} \
         samples, width {WIDTH} ==",
    );
    println!(
        "cost model {} (t_full {:.1} ms, t_window {:.1} ms), batch beta \
         {beta}",
        m.name,
        m.t_full * 1e3,
        m.t_window * 1e3
    );

    let sim = SimBackend::new(7);
    let samples = corpus(&sim, N);
    let teacher = vec![0.42f32; 64];

    let (seq_make, seq_ranks, seq_forwards) =
        run_sequential(&sim, &samples, &teacher, &m);
    let sim2 = SimBackend::new(7);
    let (int_make, int_ranks, int_forwards) =
        run_interleaved(&sim2, &samples, &teacher, &m, beta, WIDTH, None);

    // identical per-sample work: the schedule must not change any scan
    assert_eq!(seq_ranks, int_ranks,
               "interleaving changed a teacher trajectory");
    assert_eq!(seq_forwards, int_forwards,
               "schedules diverged: {seq_forwards} vs {int_forwards}");
    assert!(sim2.max_window_batch() >= 2,
            "pooled extraction must coalesce same-shape rounds");

    let tokens = (N * sim.constants().gen_train) as f64;
    let thr_seq = tokens / seq_make;
    let thr_int = tokens / int_make;
    println!(
        "sequential   makespan {seq_make:7.2} s   {thr_seq:7.1} ranks/s"
    );
    println!(
        "interleaved  makespan {int_make:7.2} s   {thr_int:7.1} ranks/s"
    );
    let ratio = thr_int / thr_seq;
    println!(
        "modeled extraction throughput: {ratio:.2}x ({seq_forwards} \
         forwards either way)"
    );
    assert!(
        ratio >= 1.5,
        "pooled extraction must deliver >= 1.5x modeled throughput at \
         {WIDTH} concurrent, got {ratio:.2}x"
    );
    d3llm::util::emit_bench_json("distill", &format!(
        "{{\"bench\":\"distill\",\"samples\":{N},\"width\":{WIDTH},\
         \"seq_makespan_s\":{seq_make:.4},\"pooled_makespan_s\":\
         {int_make:.4},\"speedup\":{ratio:.3}}}"
    ));
    println!("PASS: >= 1.5x modeled extraction throughput at {WIDTH} wide");

    shared_prefix_phase(&m, beta);
}

/// Repeated prompts + `SharedKvPool`: the second cohort adopts the first
/// cohort's teacher pages, skips its prompt prefills, and reproduces the
/// identical ranks.
fn shared_prefix_phase(m: &GpuCostModel, beta: f64) {
    let sim = SimBackend::new(7);
    let spec = sim.model_spec("main").expect("sim spec").clone();
    let c = sim.constants().clone();
    let mut samples = corpus(&sim, WIDTH);
    let repeat = samples.clone();
    samples.extend(repeat);

    let kv = SharedKvPool::new(KvPoolCfg {
        layers: spec.n_layers,
        d_kv: spec.d_kv,
        s_max: c.s_max,
        page_rows: c.block,
        budget_bytes: 1 << 20,
    });
    let teacher = vec![0.42f32; 64];
    let (_, ranks, _) =
        run_interleaved(&sim, &samples, &teacher, m, beta, WIDTH, Some(&kv));
    for i in 0..WIDTH {
        assert_eq!(ranks[i], ranks[i + WIDTH],
                   "shared-prefix extraction diverged on sample {i}");
    }
    let skips = kv.stats().prefill_skips;
    assert_eq!(sim.prefill_calls(), WIDTH,
               "repeated prompts must not re-run the prompt prefill");
    assert!(skips >= WIDTH as u64,
            "expected >= {WIDTH} prefill skips, saw {skips}");
    println!(
        "PASS: shared-prefix extraction skipped {skips} prompt prefills \
         with bit-identical ranks"
    );
}
