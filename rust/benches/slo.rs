//! `cargo bench --bench slo` — deadline-aware serving under a
//! deterministic 2x-overload burst (SimBackend + virtual clock, no
//! artifacts, no wall-time dependence).
//!
//! The bench drives the real serving admission/scheduling stack — the
//! deadline-aware `Batcher` and the EDF `SessionPool` — the same way the
//! engine worker does, but on a virtual millisecond clock that advances
//! by a constant `ROUND_MS` per pool round. Request cost is calibrated
//! first (one solo session's round count), so the offered load is exactly
//! `OVERLOAD`x the width-limited service rate regardless of decode-policy
//! details.
//!
//! Workload mix per five arrivals: 1 interactive (priority 2, tight
//! deadline), 1 standard (priority 1, relaxed deadline), 3 batch
//! (priority 0, no deadline) — the deadlined classes together offer 0.8x
//! the width-limited service rate (stably servable), while batch alone
//! offers 1.2x, so the entire excess is batch work.
//!
//! Acceptance (asserted):
//!   * every interactive request is served within its deadline (zero
//!     sheds, zero misses, p99 total latency <= budget);
//!   * the excess load is shed with a `retry_after_ms` hint, and the
//!     shedding lands on the batch class, never on interactive;
//!   * the batcher accounting invariant holds and nothing is dropped
//!     silently (served + shed == offered; no legacy full-queue rejects).
//!
//! Emits `BENCH_slo.json`: per-class p50/p95/p99 queue/decode/total
//! latency, served/shed/miss counts, and the overall shed rate.

use d3llm::coordinator::batcher::{Admission, Batcher};
use d3llm::coordinator::protocol::SloClass;
use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{DecodeCfg, DecodeSession, SimBackend, Strategy};
use d3llm::util::json::Json;
use d3llm::util::stats::Summary;

/// Virtual duration of one pool round (ms).
const ROUND_MS: f64 = 5.0;
const GEN_LEN: usize = 32;
/// Pool slots (live sessions) and EDF round width (sessions stepped).
const MAX_LIVE: usize = 4;
const ROUND_WIDTH: usize = 2;
const MAX_QUEUE: usize = 8;
const N_REQUESTS: usize = 120;
const OVERLOAD: f64 = 2.0;

fn cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false; // sim argmax never emits EOS by default
    cfg
}

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(8 + k % 5)).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

fn class_of(i: usize) -> SloClass {
    match i % 5 {
        0 => SloClass::Interactive,
        1 => SloClass::Standard,
        _ => SloClass::Batch,
    }
}

fn priority_of(c: SloClass) -> i64 {
    match c {
        SloClass::Interactive => 2,
        SloClass::Standard => 1,
        SloClass::Batch => 0,
    }
}

struct Meta {
    class: SloClass,
    arrival_ms: f64,
    admit_ms: f64,
}

#[derive(Default)]
struct ClassAgg {
    served: usize,
    shed: usize,
    missed: usize,
    queue_ms: Vec<f64>,
    decode_ms: Vec<f64>,
    total_ms: Vec<f64>,
}

fn main() {
    let sim = SimBackend::new(7);
    let params = vec![0.5f32; 8];

    // ---- calibrate: rounds one request needs, solo
    let mut solo =
        DecodeSession::new(&sim, cfg(), &prompt_for(0), GEN_LEN).unwrap();
    let mut solo_rounds = 1u64; // the finishing step counts too
    while !solo.step(&sim, &params).unwrap() {
        solo_rounds += 1;
    }
    let service_ms = solo_rounds as f64 * ROUND_MS;
    // width-limited service: ROUND_WIDTH session-steps per ROUND_MS, so
    // one completion every service_ms / ROUND_WIDTH on average
    let inter_arrival_ms = service_ms / ROUND_WIDTH as f64 / OVERLOAD;
    let interactive_budget = (4.0 * service_ms).ceil() as u64;
    let standard_budget = (10.0 * service_ms).ceil() as u64;
    let budget_of = |c: SloClass| match c {
        SloClass::Interactive => Some(interactive_budget),
        SloClass::Standard => Some(standard_budget),
        SloClass::Batch => None,
    };
    println!(
        "== SLO shedding: {N_REQUESTS} requests at {OVERLOAD}x overload ==\n\
         request cost {solo_rounds} rounds x {ROUND_MS} ms = {service_ms} \
         ms; arrivals every {inter_arrival_ms:.2} ms; deadlines \
         interactive {interactive_budget} ms / standard {standard_budget} \
         ms / batch none"
    );

    // ---- the burst, on a virtual clock
    let mut meta: Vec<Meta> = (0..N_REQUESTS)
        .map(|i| Meta {
            class: class_of(i),
            arrival_ms: i as f64 * inter_arrival_ms,
            admit_ms: 0.0,
        })
        .collect();
    let mut agg = [ClassAgg::default(), ClassAgg::default(),
                   ClassAgg::default()];
    let mut batcher: Batcher<usize> = Batcher::new(MAX_QUEUE);
    let mut pool: SessionPool<usize> = SessionPool::new();
    pool.set_round_width(ROUND_WIDTH);
    let mut now_ms = 0.0f64;
    let mut next_arrival = 0usize;
    let mut answered = 0usize;

    while next_arrival < N_REQUESTS || !batcher.is_empty() || !pool.is_empty()
    {
        // arrivals due by the current virtual time go through the same
        // deadline-aware admission the engine worker uses
        while next_arrival < N_REQUESTS
            && meta[next_arrival].arrival_ms <= now_ms
        {
            let i = next_arrival;
            next_arrival += 1;
            let c = meta[i].class;
            let deadline_at =
                budget_of(c).map(|b| now_ms as u64 + b);
            match batcher.admit(i, priority_of(c), deadline_at,
                                now_ms as u64) {
                Admission::Admitted(None) => {}
                Admission::Admitted(Some(evicted)) => {
                    let v = evicted.payload;
                    agg[meta[v].class.idx()].shed += 1;
                    answered += 1;
                }
                Admission::Shed { payload, retry_after_ms } => {
                    assert!(retry_after_ms >= 1,
                            "shed reply must carry a usable retry hint");
                    agg[meta[payload].class.idx()].shed += 1;
                    answered += 1;
                }
            }
        }

        // admit queued jobs into free pool slots, most urgent first
        while pool.len() < MAX_LIVE {
            let Some(q) = batcher.pop() else { break };
            let deadline_at = q.deadline_at_ms;
            let i = q.payload;
            meta[i].admit_ms = now_ms;
            let s = DecodeSession::new(&sim, cfg(), &prompt_for(i), GEN_LEN)
                .unwrap();
            pool.admit_deadline(format!("r{i}"), i, s, deadline_at);
        }

        if pool.is_empty() {
            // idle gap before the next arrival: jump the clock
            if next_arrival < N_REQUESTS {
                now_ms = now_ms.max(meta[next_arrival].arrival_ms);
            }
            continue;
        }

        pool.set_now_ms(now_ms as u64);
        let finished = pool.step_round(&sim, &params);
        now_ms += ROUND_MS;
        batcher.observe_round_ms(ROUND_MS);
        for f in finished {
            let m = &meta[f.tag];
            let a = &mut agg[m.class.idx()];
            f.result.expect("sim decode");
            a.served += 1;
            answered += 1;
            if f.deadline_missed {
                a.missed += 1;
            }
            a.queue_ms.push(m.admit_ms - m.arrival_ms);
            a.decode_ms.push(now_ms - m.admit_ms);
            a.total_ms.push(now_ms - m.arrival_ms);
        }
    }

    // ---- accounting: every request answered exactly once, invariant holds
    assert_eq!(answered, N_REQUESTS, "requests vanished without an answer");
    assert_eq!(
        batcher.enqueued_total,
        batcher.popped_total + batcher.evicted_total,
        "batcher accounting invariant violated at drain"
    );
    assert_eq!(batcher.rejected_total, 0,
               "deadline-aware admission must never hard-reject");

    // ---- SLO acceptance
    let int = &agg[SloClass::Interactive.idx()];
    let bat = &agg[SloClass::Batch.idx()];
    let int_total = Summary::of(&int.total_ms);
    assert!(int.served > 0, "no interactive request was served");
    assert_eq!(int.shed, 0, "interactive requests must not be shed at 2x");
    assert_eq!(int.missed, 0, "interactive deadline misses at 2x overload");
    assert!(
        int_total.p99 <= interactive_budget as f64,
        "interactive p99 {:.1} ms exceeds the {interactive_budget} ms budget",
        int_total.p99
    );
    let shed_all: usize = agg.iter().map(|a| a.shed).sum();
    assert!(shed_all > 0, "a 2x burst must shed some excess load");
    assert!(bat.shed * 5 >= shed_all * 4,
            "shedding should land on the batch class ({} of {shed_all} \
             were batch)", bat.shed);

    // ---- report + BENCH json
    let mut classes = Vec::new();
    for c in SloClass::ALL {
        let a = &agg[c.idx()];
        let (q, d, t) = (Summary::of(&a.queue_ms), Summary::of(&a.decode_ms),
                         Summary::of(&a.total_ms));
        println!(
            "{:<12} served {:3}  shed {:3}  miss {:2}   queue p50/p99 \
             {:6.1}/{:6.1} ms   decode p50/p99 {:6.1}/{:6.1} ms   total \
             p99 {:6.1} ms",
            c.name(), a.served, a.shed, a.missed, q.p50, q.p99, d.p50,
            d.p99, t.p99
        );
        classes.push(Json::obj(vec![
            ("class", Json::str(c.name())),
            ("served", Json::num(a.served as f64)),
            ("shed", Json::num(a.shed as f64)),
            ("deadline_miss", Json::num(a.missed as f64)),
            ("queue_ms_p50", Json::num(q.p50)),
            ("queue_ms_p95", Json::num(q.p95)),
            ("queue_ms_p99", Json::num(q.p99)),
            ("decode_ms_p50", Json::num(d.p50)),
            ("decode_ms_p95", Json::num(d.p95)),
            ("decode_ms_p99", Json::num(d.p99)),
            ("total_ms_p99", Json::num(t.p99)),
        ]));
    }
    let shed_rate = shed_all as f64 / N_REQUESTS as f64;
    let j = Json::obj(vec![
        ("bench", Json::str("slo")),
        ("requests", Json::num(N_REQUESTS as f64)),
        ("overload_x", Json::num(OVERLOAD)),
        ("round_ms", Json::num(ROUND_MS)),
        ("service_ms", Json::num(service_ms)),
        ("round_width", Json::num(ROUND_WIDTH as f64)),
        ("shed_rate", Json::num(shed_rate)),
        ("preempted_rounds", Json::num(pool.preempted_total as f64)),
        ("deadline_misses", Json::num(pool.deadline_miss_total as f64)),
        ("classes", Json::Arr(classes)),
    ]);
    d3llm::util::emit_bench_json("slo", &j.to_string());
    println!(
        "PASS: interactive SLO held at {OVERLOAD}x overload (p99 {:.1} ms \
         <= {interactive_budget} ms) while {shed_all} excess requests were \
         shed with retry hints ({:.0}% of offered load)",
        int_total.p99,
        shed_rate * 100.0
    );
}
