//! `cargo bench --bench hotpath` — serving hot-path latency (no criterion
//! offline; harness = false + util::stats).
//!
//! Covers: prefill/decode executables in both hot-path variants
//! (Pallas kernels vs fused-XLA), the AR step, host-dispatch overhead, and
//! the per-strategy end-to-end decode of one request. Skips politely when
//! artifacts/ is missing.

use d3llm::data::{self, Family};
use d3llm::decode::{self, DecodeCfg, Strategy};
use d3llm::model::{exec, KvCache, ParamStore};
use d3llm::runtime::Engine;
use d3llm::tokenizer::Tokenizer;
use d3llm::util::stats::{bench, bench_line};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("skipping hotpath bench: run `make artifacts` first");
        return Ok(());
    }
    let eng = Engine::load("artifacts")?;
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main")?.clone();
    let params = ParamStore::load("checkpoints/d3llm-llada.ckpt")
        .map(|p| p.data)
        .unwrap_or_else(|_| ParamStore::init(&spec, 7).data);

    println!("== executable latency ==");
    let tokens: Vec<i32> = (0..c.s_max as i32).map(|i| 5 + i % 90).collect();
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < 256 { 1.0 } else { 0.0 }).collect();
    for variant in ["xla", "pallas"] {
        let name = format!("prefill_{variant}");
        let secs = bench(2, 10, || {
            exec::prefill(&eng, &name, &params, &tokens, &valid).unwrap();
        });
        println!("{}", bench_line(&name, &secs));
    }

    let cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);
    let win_tokens = vec![c.mask_id; c.window];
    let win_pos: Vec<i32> = (0..c.window as i32).collect();
    let win_valid = vec![1.0f32; c.window];
    for variant in ["xla", "pallas"] {
        let name = format!("decode_{variant}");
        let secs = bench(2, 20, || {
            exec::decode_window(&eng, &name, &params, &win_tokens, &win_pos,
                                &win_valid, &cache)
                .unwrap();
        });
        println!("{}", bench_line(&name, &secs));
    }
    let secs = bench(4, 40, || {
        exec::decode_window(&eng, "ar_step", &params, &[5], &[0], &[1.0],
                            &cache)
            .unwrap();
    });
    println!("{}", bench_line("ar_step", &secs));

    println!("\n== end-to-end decode (1 GSM8K request, gen 96) ==");
    let tk = Tokenizer::new(c.vocab)?;
    let sample = &data::eval_set(&tk, Family::Gsm8k, 1, 3)[0];
    for strategy in [Strategy::Ar, Strategy::Vanilla, Strategy::FastDllm,
                     Strategy::D2f, Strategy::D3llm] {
        let cfg = DecodeCfg::preset(strategy);
        let secs = bench(1, 3, || {
            decode::generate(&eng, &cfg, &params, None, &sample.prompt, 96)
                .unwrap();
        });
        println!("{}", bench_line(strategy.name(), &secs));
    }
    Ok(())
}
