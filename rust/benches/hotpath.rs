//! `cargo bench --bench hotpath` — serving hot-path bars (harness =
//! false + util::stats; no criterion offline).
//!
//! Deterministic section (always runs, no artifacts needed):
//!
//!   1. **Zero staged bytes on the paged path**: against a synthetic
//!      manifest-v2 artifact set, an eligible decode routes to the paged
//!      lowering and `KvStaging` is never touched — `stage_calls == 0`
//!      and `bytes_copied == 0`, for a pooled page-table view *and* a
//!      dense cache, on the buffered and the literal call path.
//!   2. **Pinned fallback**: an ABI page-size mismatch falls back to the
//!      legacy staged dense path with a path-deterministic error, and the
//!      staging scratch is exercised exactly once per attempted forward.
//!   3. **Bit-identity**: every one of the seven decode strategies
//!      produces token-for-token, forward-for-forward identical output
//!      over a paged pool view vs. the dense-gather reference
//!      (SimBackend, the CI source of truth).
//!   4. **One device call per coalesced round**: a `SessionPool` round of
//!      B lockstep sessions issues exactly one batched backend call per
//!      same-shape group and zero per-item fallback calls.
//!
//! Artifact-gated section (skipped politely when artifacts/ is missing):
//! prefill/decode executable latency in both hot-path variants (Pallas
//! kernels vs fused-XLA), the AR step, and per-strategy end-to-end decode
//! of one request. Emits a BENCH json record (persisted by CI via
//! `BENCH_JSON_DIR`).

use std::path::PathBuf;

use d3llm::coordinator::scheduler::SessionPool;
use d3llm::data::{self, Family};
use d3llm::decode::{self, Backend, DecodeCfg, DecodeSession, GenResult,
                    SimBackend, Strategy};
use d3llm::model::kv_pool::{KvPoolCfg, PagedKv, SharedKvPool};
use d3llm::model::{exec, KvCache, KvView, ParamStore};
use d3llm::runtime::Engine;
use d3llm::tokenizer::Tokenizer;
use d3llm::util::emit_bench_json;
use d3llm::util::stats::{bench, bench_line};

/// Sessions in the coalesced-round phase (one group per round).
const ROUND_SESSIONS: usize = 4;
const GEN_LEN: usize = 64;

/// Synthetic manifest v2: a dense `decode_xla` plus its paged lowering
/// (`decode_paged_xla`, page-table ABI 2 rows x 8 pages = S_max 16).
/// Mirrors tests/exec_shapes.rs; the vendored offline xla stub validates
/// every argument shape for real and only refuses the final execute.
const MANIFEST_V2: &str = r#"{
  "format_version": 2,
  "constants": {"vocab":128,"pad_id":0,"mask_id":1,"eos_id":2,"bos_id":3,
    "sep_id":4,"s_max":16,"s_train":8,"gen_max":8,"gen_train":4,
    "window":2,"block":2,"verify_w":2,"b_train":1,"b_traj":1,
    "rank_never":100000},
  "models": {"main": {"name":"main","d_model":4,"n_layers":1,"n_heads":2,
    "d_head":2,"d_ff":8,"vocab":128,"s_max":16,"d_kv":4,
    "total_params":4,
    "param_layout":[
      {"name":"w","shape":[4],"offset":0,"size":4,"init":"normal"}]}},
  "executables": [{"name":"decode_xla","file":"decode_xla.hlo.txt",
    "model":"main",
    "inputs":[
      {"name":"params","shape":[4],"dtype":"f32"},
      {"name":"win_tokens","shape":[2],"dtype":"i32"},
      {"name":"win_pos","shape":[2],"dtype":"i32"},
      {"name":"win_valid","shape":[2],"dtype":"f32"},
      {"name":"kcache","shape":[1,16,4],"dtype":"f32"},
      {"name":"vcache","shape":[1,16,4],"dtype":"f32"},
      {"name":"cvalid","shape":[16],"dtype":"f32"}],
    "outputs":[
      {"name":"argmax","shape":[2],"dtype":"i32"},
      {"name":"conf","shape":[2],"dtype":"f32"},
      {"name":"entropy","shape":[2],"dtype":"f32"},
      {"name":"k_win","shape":[1,2,4],"dtype":"f32"},
      {"name":"v_win","shape":[1,2,4],"dtype":"f32"}]},
   {"name":"decode_paged_xla","file":"decode_paged_xla.hlo.txt",
    "model":"main","paged":{"page_rows":2,"max_pages":8},
    "inputs":[
      {"name":"params","shape":[4],"dtype":"f32"},
      {"name":"win_tokens","shape":[2],"dtype":"i32"},
      {"name":"win_pos","shape":[2],"dtype":"i32"},
      {"name":"win_valid","shape":[2],"dtype":"f32"},
      {"name":"k_pages","shape":[1,8,2,4],"dtype":"f32"},
      {"name":"v_pages","shape":[1,8,2,4],"dtype":"f32"},
      {"name":"page_index","shape":[8],"dtype":"i32"},
      {"name":"page_valid","shape":[8],"dtype":"i32"}],
    "outputs":[
      {"name":"argmax","shape":[2],"dtype":"i32"},
      {"name":"conf","shape":[2],"dtype":"f32"},
      {"name":"entropy","shape":[2],"dtype":"f32"},
      {"name":"k_win","shape":[1,2,4],"dtype":"f32"},
      {"name":"v_win","shape":[1,2,4],"dtype":"f32"}]}]
}"#;

fn synthetic_v2_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("d3llm_hotpath_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST_V2).unwrap();
    std::fs::write(dir.join("decode_xla.hlo.txt"), "HloModule decode_xla\n")
        .unwrap();
    std::fs::write(dir.join("decode_paged_xla.hlo.txt"),
                   "HloModule decode_paged_xla\n")
        .unwrap();
    dir
}

fn mini_pool(page_rows: usize) -> SharedKvPool {
    SharedKvPool::new(KvPoolCfg {
        layers: 1,
        d_kv: 4,
        s_max: 16,
        page_rows,
        budget_bytes: 1 << 16,
    })
}

/// Phase 1+2: paged-executable routing stages zero bytes; the ABI-
/// mismatch fallback stages deterministically. Returns the staged byte
/// count observed on the paged path (the headline bar: must be 0).
fn paged_zero_staging_phase() -> u64 {
    let params = vec![0.0f32; 4];
    let toks = [5i32, 6];
    let pos = [0i32, 1];
    let valid = [1.0f32, 1.0];
    let full: Vec<f32> = (0..64).map(|i| i as f32).collect(); // [1,16,4]

    // ---- paged path: pooled view + dense cache, both call paths
    let eng = Engine::load(synthetic_v2_dir("paged")).unwrap();
    let pool = mini_pool(2);
    let mut paged = PagedKv::admit(&pool, &[], "t", 0, 16, false).unwrap();
    paged.install_full(&full, &full, 0, 6).unwrap();
    let mut dense = KvCache::new(1, 16, 4);
    KvView::install_full(&mut dense, &full, &full, 0, 6).unwrap();
    let views: [&dyn KvView; 2] = [&paged, &dense];
    let mut paged_calls = 0usize;
    for view in views {
        for buffered in [true, false] {
            eng.set_buffered(buffered);
            let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                        &pos, &valid, view)
                .unwrap_err()
                .to_string();
            assert!(e.contains("decode_paged_xla")
                        && e.contains("offline xla stub cannot execute"),
                    "buffered={buffered}: the paged lowering must serve \
                     the call cleanly up to execute, got: {e}");
            paged_calls += 1;
        }
    }
    let paged_stats = eng.kv_stage_stats();
    assert_eq!(paged_stats.stage_calls, 0, "paged path must never stage");
    assert_eq!(paged_stats.bytes_copied, 0, "paged path must stage 0 bytes");
    println!(
        "paged-executable path: {paged_calls} forwards (pooled + dense x \
         buffered + literal), staged bytes {} / stage calls {}",
        paged_stats.bytes_copied, paged_stats.stage_calls
    );

    // ---- fallback: pool pages of 4 rows != the ABI's 2 rows per entry
    let eng = Engine::load(synthetic_v2_dir("fallback")).unwrap();
    let pool = mini_pool(4);
    let mut view = PagedKv::admit(&pool, &[], "t", 0, 16, false).unwrap();
    view.install_full(&full, &full, 0, 6).unwrap();
    let mut errs = Vec::new();
    for buffered in [true, false] {
        eng.set_buffered(buffered);
        let e = exec::decode_window(&eng, "decode_xla", &params, &toks,
                                    &pos, &valid, &view)
            .unwrap_err()
            .to_string();
        assert!(e.contains("`decode_xla`"),
                "buffered={buffered}: must fall back to the dense \
                 lowering, got: {e}");
        errs.push(e.replace(" (buffered)", ""));
    }
    assert_eq!(errs[0], errs[1], "fallback must be path-deterministic");
    let st = eng.kv_stage_stats();
    assert_eq!(st.stage_calls, 2, "legacy path stages once per forward");
    assert!(st.bytes_copied > 0, "legacy path copies pages");
    println!(
        "ABI-mismatch fallback: legacy staged path exercised ({} stage \
         calls, {} B copied), error pinned across call paths",
        st.stage_calls, st.bytes_copied
    );
    paged_stats.bytes_copied
}

/// Phase 3: every strategy decodes bit-identically over a paged view.
fn strategy_identity_phase(sim: &SimBackend, params: &[f32]) {
    let draft = vec![0.25f32; 8];
    let c = sim.constants().clone();
    let spec = sim.model_spec("main").unwrap().clone();
    let prompt: Vec<i32> = (0..14).map(|i| 5 + (i % 80) as i32).collect();
    for s in Strategy::ALL {
        let mut cfg = DecodeCfg::preset(s);
        cfg.early_stop = false;
        let mut d = DecodeSession::with_draft(sim, cfg.clone(), &prompt,
                                              GEN_LEN, Some(&draft))
            .expect("dense session");
        while !d.step(sim, params).expect("dense step") {}
        let dense = d.finish();

        let base = KvPoolCfg {
            layers: spec.n_layers,
            d_kv: spec.d_kv,
            s_max: c.s_max,
            page_rows: c.block,
            budget_bytes: 0,
        };
        let pool = SharedKvPool::new(KvPoolCfg {
            budget_bytes: 2 * base.dense_session_bytes(),
            ..base
        });
        let mut p = DecodeSession::with_pool(sim, cfg, &prompt, GEN_LEN,
                                             Some(&draft), &pool)
            .expect("pooled session");
        while !p.step(sim, params).expect("pooled step") {}
        let paged = p.finish();

        assert_eq!(paged.tokens, dense.tokens, "{} tokens", s.name());
        assert_eq!(paged.forwards, dense.forwards, "{} forwards", s.name());
        assert_eq!(paged.unmasked, dense.unmasked, "{} unmasked", s.name());
        println!(
            "  {:<10} {} tokens, {} forwards: paged == dense",
            s.name(),
            dense.tokens.len(),
            dense.forwards
        );
    }
}

/// Phase 4: B lockstep sessions coalesce into exactly one batched
/// backend call per round. Returns (rounds, batched calls, items).
fn coalesced_rounds_phase(sim: &SimBackend, params: &[f32])
                          -> (usize, usize, usize) {
    let prompt: Vec<i32> = (0..14).map(|i| 7 + (i % 60) as i32).collect();
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false;

    // sequential reference for the bit-identity cross-check
    let solo = {
        let mut s = DecodeSession::new(sim, cfg.clone(), &prompt, GEN_LEN)
            .expect("solo session");
        while !s.step(sim, params).expect("solo step") {}
        s.finish()
    };

    let mut sched: SessionPool<usize> = SessionPool::new();
    for i in 0..ROUND_SESSIONS {
        let s = DecodeSession::new(sim, cfg.clone(), &prompt, GEN_LEN)
            .expect("pool session");
        sched.admit(format!("s{i}"), i, s);
    }

    let (mut rounds, mut batched_calls, mut items) = (0usize, 0usize, 0usize);
    let mut done: Vec<Option<GenResult>> =
        (0..ROUND_SESSIONS).map(|_| None).collect();
    while !sched.is_empty() {
        let b0 = sim.prefill_batch_calls() + sim.window_batch_calls();
        let i0 = sim.prefill_batch_items() + sim.window_batch_items();
        let inline0 = sim.prefill_calls() + sim.window_calls();
        for f in sched.step_round(sim, params) {
            done[f.tag] = Some(f.result.expect("pooled decode"));
        }
        let db = sim.prefill_batch_calls() + sim.window_batch_calls() - b0;
        let di = sim.prefill_batch_items() + sim.window_batch_items() - i0;
        // lockstep sessions plan the same shape every round: at most one
        // coalesced group, and every session rides it (bookkeeping /
        // retirement rounds legitimately issue zero calls)
        assert!(db <= 1,
                "round {rounds}: same-shape forwards must coalesce into \
                 one batched backend call, got {db}");
        assert_eq!(di, ROUND_SESSIONS * db,
                   "round {rounds}: every live session rides the batch");
        assert_eq!(sim.prefill_calls() + sim.window_calls(), inline0,
                   "round {rounds}: no per-item fallback calls");
        rounds += 1;
        batched_calls += db;
        items += di;
        assert!(rounds <= 4096, "round loop never terminated");
    }
    for (i, r) in done.iter().enumerate() {
        let r = r.as_ref().expect("all sessions finish");
        assert_eq!(r.tokens, solo.tokens,
                   "s{i}: batched round decode diverged from sequential");
        assert_eq!(r.forwards, solo.forwards, "s{i}: forwards");
    }
    // the fleet's device-call count equals ONE session's forward count:
    // B sessions decode for the device cost of one
    assert_eq!(batched_calls, solo.forwards,
               "coalesced fleet must issue exactly one device call per \
                per-session forward ({batched_calls} vs {})",
               solo.forwards);
    assert_eq!(items, ROUND_SESSIONS * solo.forwards);
    (rounds, batched_calls, items)
}

fn main() -> anyhow::Result<()> {
    // ---------------- deterministic hot-path bars (no artifacts) ----
    println!("== paged-executable hot path (synthetic v2 manifest) ==");
    let paged_staged_bytes = paged_zero_staging_phase();

    let sim = SimBackend::new(41);
    let params = vec![0.5f32; 8];
    println!("\n== paged vs dense bit-identity (SimBackend, 7 strategies) ==");
    strategy_identity_phase(&sim, &params);

    println!("\n== coalesced rounds ({ROUND_SESSIONS} lockstep sessions) ==");
    let (rounds, batched_calls, items) =
        coalesced_rounds_phase(&sim, &params);
    println!(
        "{rounds} rounds -> {batched_calls} batched backend calls \
         ({items} session-forwards, 0 per-item fallbacks), bit-identical \
         to the sequential decode"
    );

    emit_bench_json("hotpath", &format!(
        "{{\"bench\":\"hotpath\",\"paged_staged_bytes\":{paged_staged_bytes},\
         \"fallback_stage_calls\":2,\"strategies_bit_identical\":7,\
         \"round_sessions\":{ROUND_SESSIONS},\"rounds\":{rounds},\
         \"batched_calls\":{batched_calls},\
         \"batched_items\":{items}}}"
    ));
    println!(
        "PASS: 0 staged bytes on the paged path, deterministic fallback, \
         7/7 strategies bit-identical, 1 backend call per coalesced round"
    );

    // ---------------- artifact-gated latency section ----------------
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\nskipping latency section: run `make artifacts` first");
        return Ok(());
    }
    let eng = Engine::load("artifacts")?;
    let c = eng.manifest.constants.clone();
    let spec = eng.manifest.model("main")?.clone();
    let params = ParamStore::load("checkpoints/d3llm-llada.ckpt")
        .map(|p| p.data)
        .unwrap_or_else(|_| ParamStore::init(&spec, 7).data);

    println!("\n== executable latency ==");
    let tokens: Vec<i32> = (0..c.s_max as i32).map(|i| 5 + i % 90).collect();
    let valid: Vec<f32> =
        (0..c.s_max).map(|i| if i < 256 { 1.0 } else { 0.0 }).collect();
    for variant in ["xla", "pallas"] {
        let name = format!("prefill_{variant}");
        let secs = bench(2, 10, || {
            exec::prefill(&eng, &name, &params, &tokens, &valid).unwrap();
        });
        println!("{}", bench_line(&name, &secs));
    }

    let cache = KvCache::new(spec.n_layers, c.s_max, spec.d_kv);
    let win_tokens = vec![c.mask_id; c.window];
    let win_pos: Vec<i32> = (0..c.window as i32).collect();
    let win_valid = vec![1.0f32; c.window];
    for variant in ["xla", "pallas"] {
        // routes through `decode_paged_{variant}` when the artifact set
        // ships the paged lowering (manifest v2), staging nothing
        let name = format!("decode_{variant}");
        let secs = bench(2, 20, || {
            exec::decode_window(&eng, &name, &params, &win_tokens, &win_pos,
                                &win_valid, &cache)
                .unwrap();
        });
        println!("{}", bench_line(&name, &secs));
    }
    let secs = bench(4, 40, || {
        exec::decode_window(&eng, "ar_step", &params, &[5], &[0], &[1.0],
                            &cache)
            .unwrap();
    });
    println!("{}", bench_line("ar_step", &secs));

    println!("\n== end-to-end decode (1 GSM8K request, gen 96) ==");
    let tk = Tokenizer::new(c.vocab)?;
    let sample = &data::eval_set(&tk, Family::Gsm8k, 1, 3)[0];
    for strategy in [Strategy::Ar, Strategy::Vanilla, Strategy::FastDllm,
                     Strategy::D2f, Strategy::D3llm] {
        let cfg = DecodeCfg::preset(strategy);
        let secs = bench(1, 3, || {
            decode::generate(&eng, &cfg, &params, None, &sample.prompt, 96)
                .unwrap();
        });
        println!("{}", bench_line(strategy.name(), &secs));
    }
    Ok(())
}
