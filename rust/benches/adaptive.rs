//! `cargo bench --bench adaptive` — the adaptive parallelism controller
//! under a deterministic overload burst (SimBackend + virtual clock, no
//! artifacts, no wall-time dependence).
//!
//! Two runs over the *identical* burst — N d3llm requests arriving far
//! faster than the pool drains them — differing only in the controller
//! mode:
//!
//!   * `off`  — the static baseline: every session decodes at the preset
//!              operating point (`decode::DEFAULT_ENTROPY_THRESHOLD`);
//!   * `load` — the controller sees the batcher backlog and the full
//!              session pool (`pool_full` occupancy term), drives
//!              pressure to ~1, and raises each session's entropy
//!              threshold toward the calibrated `entropy_ceiling` (with
//!              the widest block budget), buying tokens per round.
//!
//! Acceptance (asserted):
//!   * aggregate tokens/round (total committed tokens / pool rounds) in
//!     `load` mode is >= 1.3x the static baseline;
//!   * the accuracy cost stays inside the pinned AUP floor: with the
//!     mean selection-time confidence of committed tokens as the
//!     accuracy proxy (the sim's task accuracy is degenerate — see
//!     bench-results/README.md), the adaptive single-point AUP
//!     (tokens/round x proxy) regresses at most `MAX_AUP_DELTA_FRAC`
//!     versus the static point;
//!   * no emitted threshold ever crosses the `entropy_ceiling` (the hard
//!     floor, load notwithstanding), and `off` mode emits no budgets.
//!
//! Emits `BENCH_adaptive.json` with both operating points and the gates.

use d3llm::coordinator::batcher::{Admission, Batcher};
use d3llm::coordinator::scheduler::SessionPool;
use d3llm::decode::{AdaptiveCfg, AdaptiveController, AdaptiveMode,
                    DecodeCfg, DecodeSession, LoadSignal, SimBackend,
                    Strategy};
use d3llm::metrics::aup::{aup_delta_frac, Point};
use d3llm::util::json::Json;

/// Virtual duration of one pool round (ms).
const ROUND_MS: f64 = 5.0;
/// Arrival spacing (ms): ~5 arrivals per round — a hard burst.
const INTER_ARRIVAL_MS: f64 = 1.0;
const GEN_LEN: usize = 64;
const MAX_LIVE: usize = 4;
/// Large enough that nothing sheds: both runs serve every request.
const MAX_QUEUE: usize = 64;
const N_REQUESTS: usize = 32;
const SEED: u64 = 67;
/// The throughput gate: adaptive tokens/round vs. static.
const MIN_TOKENS_PER_ROUND_X: f64 = 1.3;
/// The pinned accuracy floor: the adaptive operating point may lose at
/// most this fraction of the static point's single-point AUP.
const MAX_AUP_DELTA_FRAC: f64 = 0.10;

fn cfg() -> DecodeCfg {
    let mut cfg = DecodeCfg::preset(Strategy::D3llm);
    cfg.early_stop = false; // sim argmax never emits EOS by default
    cfg
}

fn prompt_for(k: usize) -> Vec<i32> {
    (0..(8 + k % 5)).map(|i| 5 + ((i + 3 * k) % 80) as i32).collect()
}

#[derive(Default)]
struct RunStats {
    pool_rounds: u64,
    total_tokens: u64,
    conf_sum: f64,
    quality_commits: u64,
    budgets_emitted: u64,
    max_threshold: f32,
}

impl RunStats {
    fn tokens_per_round(&self) -> f64 {
        self.total_tokens as f64 / self.pool_rounds.max(1) as f64
    }

    /// Accuracy proxy in percent: mean selection-time confidence of the
    /// tokens the run actually committed.
    fn acc_proxy(&self) -> f64 {
        100.0 * self.conf_sum / self.quality_commits.max(1) as f64
    }
}

/// One full serving run over the burst; only `mode` differs between the
/// baseline and the adaptive run.
fn run(mode: AdaptiveMode) -> RunStats {
    let sim = SimBackend::new(SEED);
    let params = vec![0.5f32; 8];
    let mut ctrl = AdaptiveController::new(AdaptiveCfg {
        mode,
        // what the serving replica loop defaults to: a full pool is load
        pool_full: MAX_LIVE,
        ..AdaptiveCfg::default()
    });
    let mut batcher: Batcher<usize> = Batcher::new(MAX_QUEUE);
    let mut pool: SessionPool<usize> = SessionPool::new();
    let mut st = RunStats::default();
    let mut now_ms = 0.0f64;
    let mut next_arrival = 0usize;

    while next_arrival < N_REQUESTS || !batcher.is_empty()
        || !pool.is_empty()
    {
        while next_arrival < N_REQUESTS
            && next_arrival as f64 * INTER_ARRIVAL_MS <= now_ms
        {
            let i = next_arrival;
            next_arrival += 1;
            let adm = batcher.admit(i, 0, None, now_ms as u64);
            assert!(matches!(adm, Admission::Admitted(None)),
                    "the bench queue must never shed");
        }
        while pool.len() < MAX_LIVE {
            let Some(q) = batcher.pop() else { break };
            let i = q.payload;
            let s = DecodeSession::new(&sim, cfg(), &prompt_for(i), GEN_LEN)
                .unwrap();
            pool.admit(format!("r{i}"), i, s);
        }
        if pool.is_empty() {
            now_ms = now_ms.max(next_arrival as f64 * INTER_ARRIVAL_MS);
            continue;
        }

        // the replica loop's controller sequence, on the virtual clock
        if ctrl.enabled() {
            ctrl.observe(&LoadSignal {
                queue_depth: batcher.len(),
                active_sessions: pool.len(),
                est_wait_ms: batcher.estimated_wait_ms(),
                round_ms: batcher.round_ms(),
            });
            pool.set_budgets(|dcfg, res| {
                let b =
                    ctrl.budget_for(dcfg.metric, res.mean_commit_entropy());
                if let Some(b) = b {
                    st.budgets_emitted += 1;
                    st.max_threshold =
                        st.max_threshold.max(b.entropy_threshold);
                }
                b
            });
        }

        pool.set_now_ms(now_ms as u64);
        let finished = pool.step_round(&sim, &params);
        st.pool_rounds += 1;
        now_ms += ROUND_MS;
        batcher.observe_round_ms(ROUND_MS);
        for f in finished {
            let r = f.result.expect("sim decode");
            st.total_tokens += r.unmasked as u64;
            st.conf_sum += r.conf_sum;
            st.quality_commits += r.quality_commits as u64;
        }
    }
    st
}

fn main() {
    println!(
        "== adaptive parallelism: {N_REQUESTS} x {GEN_LEN}-token d3llm \
         requests, {INTER_ARRIVAL_MS} ms arrivals vs {ROUND_MS} ms rounds \
         (hard burst) =="
    );
    let stat = run(AdaptiveMode::Off);
    let adap = run(AdaptiveMode::Load);

    // identical burst, fully served, both modes
    assert_eq!(stat.total_tokens, (N_REQUESTS * GEN_LEN) as u64,
               "the static run dropped tokens");
    assert_eq!(adap.total_tokens, stat.total_tokens,
               "the runs served different workloads");
    assert_eq!(stat.budgets_emitted, 0, "off mode emitted budgets");
    assert!(adap.budgets_emitted > 0, "load mode never emitted a budget");

    // ---- hard floor: no emitted threshold past the ceiling, ever
    let ceiling = AdaptiveCfg::default().entropy_ceiling;
    assert!(adap.max_threshold <= ceiling + 1e-6,
            "emitted threshold {} crossed the ceiling {ceiling}",
            adap.max_threshold);

    // ---- throughput gate
    let x = adap.tokens_per_round() / stat.tokens_per_round();
    println!(
        "static:   {:4} rounds, {:.2} tokens/round, acc proxy {:.1}",
        stat.pool_rounds, stat.tokens_per_round(), stat.acc_proxy()
    );
    println!(
        "adaptive: {:4} rounds, {:.2} tokens/round, acc proxy {:.1}  \
         (max emitted threshold {:.3}, ceiling {ceiling})",
        adap.pool_rounds, adap.tokens_per_round(), adap.acc_proxy(),
        adap.max_threshold
    );
    assert!(x >= MIN_TOKENS_PER_ROUND_X,
            "tokens/round speedup {x:.2}x under the burst is below the \
             {MIN_TOKENS_PER_ROUND_X}x gate");

    // ---- AUP regression gate (the pinned accuracy floor)
    let delta = aup_delta_frac(
        Point { rho: stat.tokens_per_round(), acc: stat.acc_proxy() },
        Point { rho: adap.tokens_per_round(), acc: adap.acc_proxy() },
    );
    assert!(delta <= MAX_AUP_DELTA_FRAC,
            "adaptive AUP regressed {:.1}% vs static (pinned floor {:.0}%)",
            delta * 100.0, MAX_AUP_DELTA_FRAC * 100.0);

    let j = Json::obj(vec![
        ("bench", Json::str("adaptive")),
        ("requests", Json::num(N_REQUESTS as f64)),
        ("gen_len", Json::num(GEN_LEN as f64)),
        ("round_ms", Json::num(ROUND_MS)),
        ("static_rounds", Json::num(stat.pool_rounds as f64)),
        ("adaptive_rounds", Json::num(adap.pool_rounds as f64)),
        ("static_tokens_per_round", Json::num(stat.tokens_per_round())),
        ("adaptive_tokens_per_round", Json::num(adap.tokens_per_round())),
        ("tokens_per_round_x", Json::num(x)),
        ("min_tokens_per_round_x", Json::num(MIN_TOKENS_PER_ROUND_X)),
        ("static_acc_proxy", Json::num(stat.acc_proxy())),
        ("adaptive_acc_proxy", Json::num(adap.acc_proxy())),
        ("aup_delta_frac", Json::num(delta)),
        ("max_aup_delta_frac", Json::num(MAX_AUP_DELTA_FRAC)),
        ("max_emitted_threshold", Json::num(adap.max_threshold as f64)),
        ("entropy_ceiling", Json::num(ceiling as f64)),
        ("budgets_emitted", Json::num(adap.budgets_emitted as f64)),
    ]);
    d3llm::util::emit_bench_json("adaptive", &j.to_string());
    println!(
        "PASS: {x:.2}x tokens/round under the burst (gate \
         {MIN_TOKENS_PER_ROUND_X}x) at {:.1}% AUP delta (pinned floor \
         {:.0}%)",
        delta * 100.0,
        MAX_AUP_DELTA_FRAC * 100.0
    );
}
