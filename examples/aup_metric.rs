//! AUP metric walkthrough (paper §2, Figure 1): how the weighted area
//! rewards parallelism gains that preserve accuracy and suppresses gains
//! bought with accuracy collapse. Pure metric math — no model needed.
//!
//!   cargo run --release --example aup_metric

use d3llm::metrics::aup::{aup_from_points, Point};

fn show(name: &str, pts: &[Point]) {
    print!("{name:34}");
    for alpha in [1.0, 3.0, 10.0] {
        print!("  a={alpha:<3} {:8.1}", aup_from_points(pts, alpha, None));
    }
    println!();
}

fn main() {
    // method A: raises parallelism 1 -> 6 with no accuracy loss
    let flat = [
        Point { rho: 1.0, acc: 75.0 },
        Point { rho: 3.0, acc: 75.0 },
        Point { rho: 6.0, acc: 75.0 },
    ];
    // method B: same parallelism, pays 4 accuracy points
    let droop = [
        Point { rho: 1.0, acc: 75.0 },
        Point { rho: 3.0, acc: 73.0 },
        Point { rho: 6.0, acc: 71.0 },
    ];
    // method C: spectacular TPF but accuracy collapses -> points below
    // y_min = y1 - 5 are discarded entirely
    let collapse = [
        Point { rho: 1.0, acc: 75.0 },
        Point { rho: 4.0, acc: 72.0 },
        Point { rho: 20.0, acc: 31.0 },
    ];
    // method D: vanilla (single operating point): AUP = rho * acc
    let vanilla = [Point { rho: 1.0, acc: 75.0 }];

    println!("AUP under different penalty strengths (alpha):\n");
    show("A: lossless parallelism", &flat);
    show("B: mild accuracy cost", &droop);
    show("C: accuracy collapse (clipped)", &collapse);
    show("D: vanilla single point", &vanilla);

    println!(
        "\nProperties:\n\
         - A reduces to plain AUC (weight = 1 everywhere)\n\
         - B < A at every alpha, and the gap widens with alpha\n\
         - C's collapsed point contributes nothing (below y1 - 5)\n\
         - D anchors the scale: AUP = 1.0 x 75 = 75"
    );
}
