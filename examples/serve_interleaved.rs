//! Interleaved serving demo: starts the coordinator twice — once with
//! `max_concurrent_sessions = 1` (classic batch=1 serving) and once with
//! an interleaving pool — fires the same batch of concurrent requests at
//! each, and compares per-request latency shape. While the wide run is in
//! flight it polls `{"cmd":"stats"}` to show the live queue-depth /
//! active-session gauges the engine worker exports.
//!
//!   make artifacts && repro train-all      # once
//!   cargo run --release --example serve_interleaved -- \
//!       --requests 8 --max-sessions 8
//!
//! Skips politely when artifacts/ is missing (the deterministic
//! scheduler behavior is covered without artifacts by
//! tests/scheduler_determinism.rs and benches/interleave.rs).

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use d3llm::coordinator::{self, client_request, ServerCfg};
use d3llm::data::{self, Family};
use d3llm::decode::Strategy;
use d3llm::tokenizer::Tokenizer;
use d3llm::util::cli::Args;
use d3llm::util::json;
use d3llm::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("skipping serve_interleaved: run `make artifacts` first");
        return Ok(());
    }
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 8);
    let width = args.usize_or("max-sessions", 8);
    let base_port = args.usize_or("port", 7117) as u16;
    let ckpt = args.str_or("ckpt", "d3llm-llada");

    let tk = Tokenizer::new(128)?;
    let samples = data::eval_set(&tk, Family::Gsm8k, n_requests, 7);
    let prompts: Vec<String> =
        samples.iter().map(|s| tk.decode(&s.prompt)).collect();

    println!("== serve_interleaved: {n_requests} concurrent requests ==");
    let lat1 = run_once(&ckpt, base_port, 1, &prompts)?;
    let latn = run_once(&ckpt, base_port + 1, width, &prompts)?;

    let (a, b) = (Summary::of(&lat1), Summary::of(&latn));
    println!("\nwidth 1      lat p50 {:7.0} ms   p95 {:7.0} ms   max {:7.0} ms",
             a.p50 * 1e3, a.p95 * 1e3, a.max * 1e3);
    println!("width {width:<6} lat p50 {:7.0} ms   p95 {:7.0} ms   max {:7.0} ms",
             b.p50 * 1e3, b.p95 * 1e3, b.max * 1e3);
    println!("\ninterleaving bounds head-of-line blocking: a short request \
              now waits one round, not a full decode");
    Ok(())
}

fn run_once(ckpt: &str, port: u16, width: usize, prompts: &[String])
            -> anyhow::Result<Vec<f64>> {
    let cfg = ServerCfg {
        host: "127.0.0.1".into(),
        port,
        ckpt: ckpt.to_string(),
        strategy: Strategy::D3llm,
        variant: "xla".into(),
        max_queue: 256,
        max_concurrent_sessions: width,
        draft: None,
        kv_budget_mb: 256,
        slo_round_width: 0,
        workers: 1,
        spill_after_rounds: 0,
        adaptive: Default::default(),
        decode: None,
    };
    std::thread::spawn(move || {
        if let Err(e) = coordinator::serve(cfg) {
            eprintln!("server: {e:#}");
        }
    });
    let addr = format!("127.0.0.1:{port}");
    wait_for_server(&addr)?;
    println!("\n-- width {width} on {addr} --");

    // live gauge monitor (the per-session progress the worker publishes)
    let stop = Arc::new(AtomicBool::new(false));
    let mon_stop = stop.clone();
    let mon_addr = addr.clone();
    let monitor = std::thread::spawn(move || {
        let mut peak_active = 0usize;
        while !mon_stop.load(Ordering::Relaxed) {
            if let Ok(resp) = client_request(&mon_addr, r#"{"cmd":"stats"}"#) {
                if let Ok(j) = json::parse(&resp) {
                    let active = j
                        .get("active_sessions")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0);
                    let depth = j
                        .get("queue_depth")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0);
                    if active > peak_active {
                        peak_active = active;
                        println!("   [stats] active={active} queued={depth}");
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(200));
        }
        peak_active
    });

    // fire all requests concurrently
    let mut handles = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        let addr = addr.clone();
        // build through the JSON writer so prompts with quotes/backslashes
        // stay well-formed
        let line = json::Json::obj(vec![
            ("id", json::Json::str(format!("r{i}"))),
            ("prompt", json::Json::str(prompt.clone())),
            ("gen_len", json::Json::num(96.0)),
        ])
        .to_string();
        handles.push(std::thread::spawn(move || {
            let t = Instant::now();
            let ok = client_request(&addr, &line)
                .ok()
                .and_then(|resp| json::parse(&resp).ok())
                .and_then(|j| j.get("ok").and_then(|v| v.as_bool()))
                == Some(true);
            (t.elapsed().as_secs_f64(), ok)
        }));
    }
    let mut latencies = Vec::new();
    for h in handles {
        let (lat, ok) = h.join().expect("client thread");
        if ok {
            latencies.push(lat);
        }
    }
    stop.store(true, Ordering::Relaxed);
    let peak = monitor.join().unwrap_or(0);
    println!("   served {} / {}   peak active sessions {}",
             latencies.len(), prompts.len(), peak);

    let _ = client_request(&addr, r#"{"cmd":"shutdown"}"#);
    std::thread::sleep(Duration::from_millis(200));
    Ok(latencies)
}


fn wait_for_server(addr: &str) -> anyhow::Result<()> {
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    anyhow::bail!("server did not come up on {addr}")
}
