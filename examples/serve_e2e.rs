//! End-to-end serving driver (the DESIGN.md validation workload): starts
//! the coordinator in-process, fires a batch of concurrent requests from
//! client threads, and reports latency percentiles, throughput, TPF and
//! accuracy — the serving-paper e2e check.
//!
//!   make artifacts && repro train-all      # once
//!   cargo run --release --example serve_e2e -- --requests 24 --clients 4
//!
//! Works against `d3llm-llada` by default; pass --ckpt/--strategy to vary.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use d3llm::coordinator::{self, ServerCfg};
use d3llm::data::{self, Family};
use d3llm::decode::Strategy;
use d3llm::tokenizer::Tokenizer;
use d3llm::util::cli::Args;
use d3llm::util::json;
use d3llm::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.usize_or("requests", 24);
    let n_clients = args.usize_or("clients", 4);
    let port = args.usize_or("port", 7113) as u16;
    let ckpt = args.str_or("ckpt", "d3llm-llada");
    let strategy = Strategy::parse(&args.str_or("strategy", "d3llm"))
        .ok_or_else(|| anyhow::anyhow!("bad strategy"))?;

    // ---- server in a background thread
    let cfg = ServerCfg {
        host: "127.0.0.1".into(),
        port,
        ckpt,
        strategy,
        variant: args.str_or("variant", "xla"),
        max_queue: 256,
        max_concurrent_sessions: args.usize_or("max-sessions", 4),
        draft: None,
        kv_budget_mb: 256,
        slo_round_width: args.usize_or("round-width", 0),
        workers: 1,
        spill_after_rounds: 0,
        adaptive: Default::default(),
        decode: None,
    };
    std::thread::spawn(move || {
        if let Err(e) = coordinator::serve(cfg) {
            eprintln!("server: {e:#}");
        }
    });

    let addr = format!("127.0.0.1:{port}");
    wait_for_server(&addr)?;

    // ---- workload: GSM8K-analog prompts
    let tk = Tokenizer::new(128)?;
    let samples = data::eval_set(&tk, Family::Gsm8k, n_requests, 7);
    let prompts: Vec<(String, String, data::Sample)> = samples
        .into_iter()
        .enumerate()
        .map(|(i, s)| (format!("r{i}"), tk.decode(&s.prompt), s))
        .collect();

    // ---- fire from client threads
    let work = Arc::new(Mutex::new(prompts));
    let results = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..n_clients {
        let work = work.clone();
        let results = results.clone();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || loop {
            let item = work.lock().unwrap().pop();
            let Some((id, prompt, sample)) = item else { break };
            let t = Instant::now();
            let line = format!(
                r#"{{"id":"{id}","prompt":"{prompt}","gen_len":96}}"#
            );
            match request(&addr, &line) {
                Ok(resp) => {
                    let latency = t.elapsed().as_secs_f64();
                    results.lock().unwrap().push((resp, latency, sample));
                }
                Err(e) => eprintln!("client error: {e:#}"),
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- report
    let results = results.lock().unwrap();
    let tk2 = Tokenizer::new(128)?;
    let mut latencies = Vec::new();
    let mut gen_tokens = 0usize;
    let mut forwards = 0usize;
    let mut correct = 0usize;
    for (resp, latency, sample) in results.iter() {
        latencies.push(*latency);
        let j = json::parse(resp).map_err(|e| anyhow::anyhow!("{e}"))?;
        if j.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            eprintln!("request failed: {resp}");
            continue;
        }
        gen_tokens +=
            j.get("gen_tokens").and_then(|v| v.as_usize()).unwrap_or(0);
        forwards += j.get("forwards").and_then(|v| v.as_usize()).unwrap_or(0);
        let tokens: Vec<i32> = j
            .get("tokens")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as i32)
                 .collect())
            .unwrap_or_default();
        correct += data::check(&tk2, sample, &tokens, false) as usize;
    }
    let lat = Summary::of(&latencies);
    println!("\n== serve_e2e report ==");
    println!("requests      {}", results.len());
    println!("clients       {n_clients}");
    println!("wall          {wall:.2} s");
    println!("throughput    {:.1} tok/s  ({:.2} req/s)",
             gen_tokens as f64 / wall, results.len() as f64 / wall);
    println!("TPF           {:.2}", gen_tokens as f64 / forwards.max(1) as f64);
    println!("accuracy      {:.1}%",
             100.0 * correct as f64 / results.len().max(1) as f64);
    println!("latency p50   {:.0} ms   p95 {:.0} ms   max {:.0} ms",
             lat.p50 * 1e3, lat.p95 * 1e3, lat.max * 1e3);

    // shut the server down
    let _ = request(&addr, r#"{"cmd":"shutdown"}"#);
    Ok(())
}

fn request(addr: &str, line: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    Ok(resp.trim().to_string())
}

fn wait_for_server(addr: &str) -> anyhow::Result<()> {
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    anyhow::bail!("server did not come up on {addr}")
}
