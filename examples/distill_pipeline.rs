//! The full distillation pipeline on a small budget, end to end:
//!
//!   1. pretrain a tiny diffusion teacher (random masking),
//!   2. extract its pseudo-trajectories (teacher sessions interleaved
//!      through the scheduler pool),
//!   3. distill a student with the paper's recipe (trajectory order +
//!      curriculum noise + curriculum window),
//!   4. compare teacher vs student TPF/accuracy under the same d3LLM
//!      multi-block decoding.
//!
//!   cargo run --release --example distill_pipeline -- --steps 120
//!
//! This is the minimal reproduction of the paper's core claim: trajectory
//! distillation buys parallelism (TPF) at roughly equal accuracy.

use d3llm::data::{main_mixture, Family};
use d3llm::decode::{DecodeCfg, Strategy};
use d3llm::eval::evaluate;
use d3llm::runtime::Engine;
use d3llm::tokenizer::Tokenizer;
use d3llm::train::{train, TrainCfg};
use d3llm::trajectory::{Curriculum, Recipe};
use d3llm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 120);
    let eng = Engine::load("artifacts")?;
    let tk = Tokenizer::new(eng.manifest.constants.vocab)?;
    let dir = std::path::Path::new("checkpoints/example");
    std::fs::create_dir_all(dir)?;

    // ---- 1. teacher
    let teacher_cfg = TrainCfg {
        name: "example-teacher".into(),
        model: "main".into(),
        recipe: Recipe::DiffusionPretrain,
        curriculum: Curriculum::paper_default(),
        steps: steps * 2,
        lr: 6e-3,
        ent_weight: 0.0,
        corpus_size: 256,
        mixture: main_mixture(),
        seed: 11,
        init_from: None,
        teacher: None,
        log_every: 50,
    };
    println!("== training teacher ({} steps) ==", teacher_cfg.steps);
    let teacher = train(&eng, &teacher_cfg, dir)?;

    // ---- 2 + 3. student distilled on the teacher's trajectories
    let student_cfg = TrainCfg {
        name: "example-student".into(),
        recipe: Recipe::PseudoTraj,
        steps,
        ent_weight: 0.2,
        init_from: Some("example-teacher".into()),
        teacher: Some("example-teacher".into()),
        ..teacher_cfg.clone()
    };
    println!("== distilling student ({steps} steps) ==");
    let student = train(&eng, &student_cfg, dir)?;

    // ---- 4. same decoding, both checkpoints
    let cfg = DecodeCfg::preset(Strategy::D3llm);
    let samples = d3llm::data::eval_set(&tk, Family::Gsm8k, 10, 5);
    for (label, params) in [("teacher", &teacher.params),
                            ("student", &student.params)] {
        let out = evaluate(&eng, &cfg, &params.data, None, &tk, &samples,
                           false)?;
        println!(
            "{label:8}  acc {:5.1}%  TPF {:.2}  forwards {}",
            out.metrics.accuracy(),
            out.metrics.tpf(),
            out.metrics.forwards
        );
    }
    println!("(student TPF should exceed teacher TPF at similar accuracy)");
    Ok(())
}
