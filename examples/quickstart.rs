//! Quickstart: load the AOT artifacts, load (or initialise) a checkpoint,
//! and decode one synthetic GSM8K-style prompt with the d3LLM multi-block
//! strategy.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! With trained checkpoints (`repro train-all`) the answer is usually
//! correct; with random init you still see the full decode pipeline run.

use d3llm::data::{self, Family};
use d3llm::decode::{self, DecodeCfg, Strategy};
use d3llm::model::ParamStore;
use d3llm::runtime::Engine;
use d3llm::tokenizer::Tokenizer;
use d3llm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. runtime: manifest + PJRT CPU client + lazy-compiled executables
    let eng = Engine::load("artifacts")?;
    let c = eng.manifest.constants.clone();
    println!("platform: {}", eng.platform());

    // 2. weights: trained checkpoint if present, random init otherwise
    let spec = eng.manifest.model("main")?.clone();
    let params = match ParamStore::load("checkpoints/d3llm-llada.ckpt") {
        Ok(p) => {
            println!("loaded checkpoints/d3llm-llada.ckpt");
            p
        }
        Err(_) => {
            println!("no checkpoint found — using random init \
                      (run `repro train-all`)");
            ParamStore::init(&spec, 7)
        }
    };

    // 3. one synthetic task
    let tk = Tokenizer::new(c.vocab)?;
    let sample = data::generate(&tk, Family::Gsm8k, &mut Rng::new(99));
    println!("prompt:   {}", tk.decode(&sample.prompt));
    println!("expected: {}", tk.decode(&sample.response));

    // 4. entropy-based multi-block decode with KV refresh (paper §3.2)
    let cfg = DecodeCfg::preset(Strategy::D3llm);
    let r = decode::generate(&eng, &cfg, &params.data, None, &sample.prompt,
                             96)?;
    println!("decoded:  {}", tk.decode(&r.tokens));
    println!(
        "tokens {}  forwards {}  TPF {:.2}  wall {:.0} ms  correct: {}",
        r.tokens.len(),
        r.forwards,
        r.tpf(),
        r.wall_secs * 1e3,
        data::check(&tk, &sample, &r.tokens, false)
    );
    Ok(())
}
